//! Threaded coordinator: `K` real worker threads, replicated Q-GenX state,
//! actual encoded bytes through the [`AllGather`] transport.
//!
//! Replication invariant: every worker decodes the *same* K payloads in the
//! same rank order, runs the same deterministic state update, and pools the
//! same sufficient statistics at level-update steps — so all replicas of
//! `QGenX`, `Levels` and the Huffman tables stay bit-identical without a
//! parameter server. (This mirrors data-parallel DDP, which is the paper's
//! deployment model.) The invariant is asserted at the end of every run by
//! comparing replica iterates across workers.

use super::pipeline::Compressor;
use super::schedule::UpdateSchedule;
use crate::algo::QGenX;
use crate::config::{ExperimentConfig, LevelScheme};
use crate::error::{Error, Result};
use crate::metrics::Recorder;
use crate::net::{AllGather, NetModel, TrafficStats};
use crate::oracle::{build_operator, build_oracle, GapEvaluator};
use crate::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Outcome of one threaded run: rank-0 recorder plus the final iterate of
/// every replica (for the replication invariant check and tests).
pub struct ThreadedRun {
    pub recorder: Recorder,
    pub replicas: Vec<Vec<f32>>,
}

/// Run Algorithm 1 on `K` OS threads. Functionally equivalent to
/// [`super::inline::run_experiment`] modulo RNG stream interleaving.
pub fn run_threaded(cfg: &ExperimentConfig) -> Result<ThreadedRun> {
    cfg.validate()?;
    let op = build_operator(&cfg.problem, cfg.seed)?;
    let d = op.dim();
    let k = cfg.workers;
    let transport = AllGather::new(k);
    let net = NetModel::from_config(&cfg.net);
    let adaptive = cfg.quant.scheme == LevelScheme::Adaptive
        || cfg.quant.codec == crate::coding::SymbolCodec::Huffman;
    let schedule = if adaptive {
        UpdateSchedule::new(cfg.quant.update_every.min(10), cfg.quant.update_every)
    } else {
        UpdateSchedule::never()
    };

    let handles: Vec<std::thread::JoinHandle<Result<(Recorder, Vec<f32>)>>> = (0..k)
        .map(|rank| {
            let op = op.clone();
            let cfg = cfg.clone();
            let transport = transport.clone();
            std::thread::Builder::new()
                .name(format!("qgenx-worker-{rank}"))
                .spawn(move || worker_loop(rank, &cfg, op, transport, net, schedule, d))
                .expect("spawn worker")
        })
        .collect();

    let mut recorders = Vec::with_capacity(k);
    let mut replicas = Vec::with_capacity(k);
    for h in handles {
        let (rec, x) = h
            .join()
            .map_err(|_| Error::Coordinator("worker thread panicked".into()))??;
        recorders.push(rec);
        replicas.push(x);
    }
    // Replication invariant: all replicas ended at the same iterate.
    for r in 1..k {
        if replicas[r] != replicas[0] {
            return Err(Error::Coordinator(format!(
                "replica divergence: worker {r} differs from worker 0"
            )));
        }
    }
    Ok(ThreadedRun { recorder: recorders.swap_remove(0), replicas })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rank: usize,
    cfg: &ExperimentConfig,
    op: Arc<dyn crate::oracle::Operator>,
    transport: Arc<AllGather>,
    net: NetModel,
    schedule: UpdateSchedule,
    d: usize,
) -> Result<(Recorder, Vec<f32>)> {
    let k = cfg.workers;
    let root = Rng::seed_from(cfg.seed);
    let mut oracle = build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (rank as u64 + 1) * 0x9e37)?;
    let mut comp = Compressor::from_config(&cfg.quant, root.fork(rank as u64 + 101))?;
    let mut state = QGenX::new(
        cfg.algo.variant,
        &vec![0.0f32; d],
        k,
        cfg.algo.gamma0,
        cfg.algo.adaptive_step,
    );
    let gap_eval = if rank == 0 { GapEvaluator::around_solution(op.as_ref(), 2.0) } else { None };
    let mut traffic = TrafficStats::default();
    let mut rec = Recorder::new();
    let mut g_buf = vec![0.0f32; d];
    let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];

    // One exchange helper: contribute my wire bytes, decode all K.
    let mut exchange = |payload: Vec<u8>,
                        comp: &Compressor,
                        decoded: &mut Vec<Vec<f32>>,
                        traffic: &mut TrafficStats|
     -> Result<()> {
        let got = transport.exchange(rank, payload);
        let bits: Vec<u64> = got.iter().map(|p| 8 * p.len() as u64).collect();
        traffic.record_allgather(&bits, &net);
        for (w, bytes) in got.iter().enumerate() {
            comp.decompress(bytes, &mut decoded[w])?;
        }
        Ok(())
    };

    for t in 1..=cfg.iters {
        // (1) stat exchange + synchronized level update
        if schedule.is_update(t) && comp.is_quantized() {
            let payload = comp.stats_payload();
            let got = transport.exchange(rank, payload);
            let bits: Vec<u64> = got.iter().map(|p| 8 * p.len() as u64).collect();
            traffic.record_allgather(&bits, &net);
            let rank_order: Vec<&[u8]> = got.iter().map(|p| p.as_slice()).collect();
            comp.update_levels(&rank_order)?;
        }

        // (2) base exchange
        let base_vecs: Vec<Vec<f32>> = if let Some(xq) = state.base_query() {
            let t0 = Instant::now();
            oracle.sample(&xq, &mut g_buf);
            let (bytes, _) = comp.compress(&g_buf)?;
            traffic.add_compute(t0.elapsed().as_secs_f64());
            exchange(bytes, &comp, &mut decoded, &mut traffic)?;
            decoded.clone()
        } else {
            Vec::new()
        };

        // (3) extrapolate (identical on every replica)
        let x_half = state.extrapolate(&base_vecs)?;

        // (4) half-step exchange
        let t0 = Instant::now();
        oracle.sample(&x_half, &mut g_buf);
        let (bytes, _) = comp.compress(&g_buf)?;
        traffic.add_compute(t0.elapsed().as_secs_f64());
        exchange(bytes, &comp, &mut decoded, &mut traffic)?;
        state.update(&decoded)?;

        // (5) rank-0 evaluation
        if rank == 0 && (t % cfg.eval_every.max(1) == 0 || t == cfg.iters) {
            let avg = state.ergodic_average();
            if let Some(ev) = &gap_eval {
                rec.push("gap", t as f64, ev.gap(op.as_ref(), &avg));
                rec.push("dist", t as f64, ev.dist_to_center(&avg));
            }
            rec.push("gamma", t as f64, state.gamma());
            rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
            rec.push("sim_time_cum", t as f64, traffic.total_time());
        }
    }
    if rank == 0 {
        rec.set_scalar("total_bits", traffic.bits_sent as f64);
        rec.set_scalar("rounds", traffic.rounds as f64);
        rec.set_scalar("level_updates", comp.updates() as f64);
    }
    Ok((rec, state.x_world()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::inline::run_experiment;

    fn cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 3;
        cfg.iters = 150;
        cfg.eval_every = 50;
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 12;
        cfg.problem.noise = "absolute".into();
        cfg.problem.sigma = 0.3;
        cfg.quant.update_every = 60;
        cfg
    }

    #[test]
    fn threaded_run_completes_and_replicas_agree() {
        let run = run_threaded(&cfg()).unwrap();
        assert_eq!(run.replicas.len(), 3);
        for r in &run.replicas[1..] {
            assert_eq!(r, &run.replicas[0]);
        }
        let gap = run.recorder.get("gap").unwrap().last().unwrap();
        assert!(gap.is_finite());
    }

    #[test]
    fn threaded_matches_inline_bit_counts() {
        // Same config: identical wire-format sizes per round in expectation;
        // totals agree because both run the same number of rounds with the
        // same quantization parameters (RNG streams differ so exact bits
        // differ slightly under Huffman/Elias; compare within 5%).
        let c = cfg();
        let inline_rec = run_experiment(&c).unwrap();
        let threaded = run_threaded(&c).unwrap();
        let bi = inline_rec.scalar("total_bits").unwrap();
        let bt = threaded.recorder.scalar("total_bits").unwrap();
        assert!(
            (bi - bt).abs() / bi < 0.05,
            "inline {bi} vs threaded {bt}"
        );
        assert_eq!(
            inline_rec.scalar("rounds").unwrap(),
            threaded.recorder.scalar("rounds").unwrap()
        );
    }

    #[test]
    fn threaded_converges() {
        let mut c = cfg();
        c.iters = 400;
        let run = run_threaded(&c).unwrap();
        let gaps = run.recorder.get("gap").unwrap();
        let first = gaps.points.first().unwrap().1;
        let last = gaps.last().unwrap();
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn threaded_fp32_mode() {
        let mut c = cfg();
        c.quant.mode = crate::config::QuantMode::Fp32;
        c.iters = 60;
        let run = run_threaded(&c).unwrap();
        // fp32: bits = 32 * d * senders * rounds exactly — deterministic.
        let bits = run.recorder.scalar("total_bits").unwrap();
        let rounds = run.recorder.scalar("rounds").unwrap();
        let expect = rounds * 3.0 * 2.0 * 32.0 * 12.0;
        assert!((bits - expect).abs() < 1e-6, "bits {bits} expect {expect}");
    }
}
