//! Running and empirical statistics.
//!
//! * [`RunningStats`] — Welford mean/variance, used by the bench harness and
//!   by the empirical-variance checks against Theorem 1.
//! * [`Histogram`] — fixed-bin histogram over `[0,1]`, the sufficient
//!   statistic QAda computes on normalized coordinates ("each processor
//!   computes sufficient statistics of a parametric distribution").
//! * [`ecdf::WeightedEcdf`] — the weighted empirical CDF `F̃(u) = Σ_j λ_j F_j(u)`
//!   of Eq. (QAda), with the λ_j = ‖g_j‖_q² / Σ ‖g_j‖_q² weighting.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        RunningStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }
}

/// Fixed-width histogram over `[0, 1]` — QAda's sufficient statistic for the
/// distribution of normalized coordinates `u_i = |v_i| / ‖v‖_q`.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<f64>,
    total: f64,
}

impl Histogram {
    pub fn new(bins: usize) -> Self {
        assert!(bins > 0);
        Histogram { counts: vec![0.0; bins], total: 0.0 }
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    /// Add one observation `u ∈ [0,1]` with weight `w`.
    #[inline]
    pub fn push_weighted(&mut self, u: f64, w: f64) {
        let b = ((u * self.counts.len() as f64) as usize).min(self.counts.len() - 1);
        self.counts[b] += w;
        self.total += w;
    }

    pub fn push(&mut self, u: f64) {
        self.push_weighted(u, 1.0);
    }

    /// Add every normalized coordinate of `v` (coordinates are normalized by
    /// `norm`), each with weight `w`. Zero coordinates are included — they
    /// matter for the `p_0` symbol probability of Theorem 2.
    pub fn push_normalized(&mut self, v: &[f32], norm: f64, w: f64) {
        if norm == 0.0 {
            return;
        }
        for &x in v {
            self.push_weighted((x.abs() as f64 / norm).min(1.0), w);
        }
    }

    pub fn total(&self) -> f64 {
        self.total
    }

    /// Probability mass of bin `b`.
    pub fn pmf(&self, b: usize) -> f64 {
        if self.total == 0.0 {
            0.0
        } else {
            self.counts[b] / self.total
        }
    }

    /// CDF evaluated at `u` (linear interpolation within the bin).
    pub fn cdf(&self, u: f64) -> f64 {
        if self.total == 0.0 {
            return 0.0;
        }
        let u = u.clamp(0.0, 1.0);
        let nb = self.counts.len() as f64;
        let pos = u * nb;
        let b = (pos as usize).min(self.counts.len() - 1);
        let frac = pos - b as f64;
        let below: f64 = self.counts[..b].iter().sum();
        (below + self.counts[b] * frac) / self.total
    }

    /// Merge another histogram (same bin count) into this one — used when
    /// the leader pools worker sufficient statistics.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Raw bin masses (for serialization across workers).
    pub fn bin_counts(&self) -> &[f64] {
        &self.counts
    }

    /// Add raw bin masses (deserialization counterpart of `bin_counts`).
    pub fn add_counts(&mut self, counts: &[f64]) {
        assert_eq!(counts.len(), self.counts.len());
        for (a, b) in self.counts.iter_mut().zip(counts.iter()) {
            *a += b;
            self.total += b;
        }
    }

    /// Empirical quantile (inverse CDF).
    pub fn quantile(&self, p: f64) -> f64 {
        if self.total == 0.0 {
            return 0.0;
        }
        let target = p.clamp(0.0, 1.0) * self.total;
        let mut acc = 0.0;
        for (b, &c) in self.counts.iter().enumerate() {
            if acc + c >= target && c > 0.0 {
                let frac = (target - acc) / c;
                return (b as f64 + frac) / self.counts.len() as f64;
            }
            acc += c;
        }
        1.0
    }
}

pub mod ecdf {
    //! Weighted empirical CDF over exact sample points (used by tests and by
    //! the level optimizer when the sample count is small enough to keep
    //! exactly; the histogram path is the streaming approximation).

    /// Weighted ECDF `F̃(u) = Σ_j λ_j 1{u_j <= u}` over stored samples.
    #[derive(Clone, Debug, Default)]
    pub struct WeightedEcdf {
        /// (value, weight), sorted by value after `finalize`.
        samples: Vec<(f64, f64)>,
        total_w: f64,
        sorted: bool,
    }

    impl WeightedEcdf {
        pub fn new() -> Self {
            Self::default()
        }

        pub fn push(&mut self, u: f64, w: f64) {
            self.samples.push((u, w));
            self.total_w += w;
            self.sorted = false;
        }

        pub fn len(&self) -> usize {
            self.samples.len()
        }

        pub fn is_empty(&self) -> bool {
            self.samples.is_empty()
        }

        pub fn finalize(&mut self) {
            self.samples
                .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            self.sorted = true;
        }

        /// CDF at `u`; requires `finalize` first.
        pub fn cdf(&self, u: f64) -> f64 {
            assert!(self.sorted, "call finalize() before cdf()");
            if self.total_w == 0.0 {
                return 0.0;
            }
            // Binary search for the last sample <= u.
            let mut lo = 0usize;
            let mut hi = self.samples.len();
            while lo < hi {
                let mid = (lo + hi) / 2;
                if self.samples[mid].0 <= u {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            let mass: f64 = self.samples[..lo].iter().map(|s| s.1).sum();
            mass / self.total_w
        }

        /// Iterate over (value, normalized weight) pairs in sorted order.
        pub fn iter_normalized(&self) -> impl Iterator<Item = (f64, f64)> + '_ {
            assert!(self.sorted);
            let t = self.total_w;
            self.samples.iter().map(move |&(u, w)| (u, w / t))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_stats_known() {
        let mut s = RunningStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // population var is 4 -> sample var = 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn histogram_cdf_monotone_and_bounded() {
        let mut h = Histogram::new(64);
        for i in 0..1000 {
            h.push((i as f64) / 1000.0);
        }
        let mut prev = 0.0;
        for i in 0..=20 {
            let u = i as f64 / 20.0;
            let c = h.cdf(u);
            assert!(c >= prev - 1e-12);
            assert!((0.0..=1.0).contains(&c));
            prev = c;
        }
        assert!((h.cdf(1.0) - 1.0).abs() < 1e-9);
        // Uniform data -> cdf(u) ~ u
        assert!((h.cdf(0.5) - 0.5).abs() < 0.05);
    }

    #[test]
    fn histogram_quantile_inverts_cdf() {
        let mut h = Histogram::new(128);
        for i in 0..10_000 {
            h.push((i as f64) / 10_000.0);
        }
        for p in [0.1, 0.25, 0.5, 0.9] {
            let q = h.quantile(p);
            assert!((h.cdf(q) - p).abs() < 0.02, "p={p} q={q}");
        }
    }

    #[test]
    fn histogram_merge_pools_mass() {
        let mut a = Histogram::new(8);
        let mut b = Histogram::new(8);
        a.push(0.1);
        b.push(0.9);
        a.merge(&b);
        assert_eq!(a.total(), 2.0);
        assert!((a.cdf(0.5) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn ecdf_weighted() {
        let mut e = ecdf::WeightedEcdf::new();
        e.push(0.2, 1.0);
        e.push(0.8, 3.0);
        e.finalize();
        assert!((e.cdf(0.1) - 0.0).abs() < 1e-12);
        assert!((e.cdf(0.5) - 0.25).abs() < 1e-12);
        assert!((e.cdf(0.9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_push_normalized_counts_zeros() {
        let mut h = Histogram::new(4);
        h.push_normalized(&[0.0, 0.5, 1.0], 1.0, 1.0);
        assert_eq!(h.total(), 3.0);
        // zero lands in first bin
        assert!(h.pmf(0) > 0.0);
    }
}
