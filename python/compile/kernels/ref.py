"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must agree with its `ref_*` counterpart bit-for-bit under interpret mode
(same dtype, same math). pytest enforces this (see python/tests).

The quantization reference mirrors Definition 1 of the paper and the Rust
implementation in `rust/src/quant/quantizer.rs`:

    u_i  = |v_i| / norm                       (norm computed by the caller)
    tau  = #{ interior levels <= u_i }
    xi   = (u_i - l_tau) / (l_{tau+1} - l_tau)
    sym  = tau + 1{ uniform_i < xi }
    out  = sign(v_i) * norm * levels[sym]
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_quantize(v, levels, uniforms, norm):
    """Stochastically quantize ``v`` against ``levels``.

    Args:
      v: f32[d] vector to quantize.
      levels: f32[L] full level sequence including endpoints 0 and 1
        (L = s + 2, strictly increasing).
      uniforms: f32[d] i.i.d. U[0,1) randomness (explicit for determinism).
      norm: f32 scalar, the L^q norm of ``v`` (0 => output all zeros).

    Returns:
      f32[d] dequantized reconstruction ``Q_l(v)``.
    """
    v = jnp.asarray(v, jnp.float32)
    levels = jnp.asarray(levels, jnp.float32)
    uniforms = jnp.asarray(uniforms, jnp.float32)
    norm = jnp.asarray(norm, jnp.float32)

    inv = jnp.where(norm > 0.0, 1.0 / norm, 0.0)
    mag = jnp.minimum(jnp.abs(v) * inv, 1.0)

    # tau = number of *interior* levels (levels[1:-1]) <= mag; a branchless
    # bin search via broadcast-compare-sum. Shape: (d,).
    interior = levels[1:-1]
    tau = jnp.sum(mag[:, None] >= interior[None, :], axis=1).astype(jnp.int32)

    lo = levels[tau]
    hi = levels[tau + 1]
    xi = (mag - lo) / (hi - lo)
    up = (uniforms < xi).astype(jnp.int32)
    sym = tau + up
    out = jnp.sign(v) * norm * levels[sym]
    return jnp.where(norm > 0.0, out, jnp.zeros_like(v))


def ref_quantize_symbols(v, levels, uniforms, norm):
    """Same math as :func:`ref_quantize` but returns the integer symbols
    (useful for wire-format parity tests against the Rust encoder)."""
    v = jnp.asarray(v, jnp.float32)
    levels = jnp.asarray(levels, jnp.float32)
    uniforms = jnp.asarray(uniforms, jnp.float32)
    norm = jnp.asarray(norm, jnp.float32)
    inv = jnp.where(norm > 0.0, 1.0 / norm, 0.0)
    mag = jnp.minimum(jnp.abs(v) * inv, 1.0)
    interior = levels[1:-1]
    tau = jnp.sum(mag[:, None] >= interior[None, :], axis=1).astype(jnp.int32)
    lo = levels[tau]
    hi = levels[tau + 1]
    xi = (mag - lo) / (hi - lo)
    up = (uniforms < xi).astype(jnp.int32)
    return jnp.where(norm > 0.0, tau + up, jnp.zeros_like(tau))


def ref_fused_extragrad(x, y, v_base, v_half, gamma_cur, gamma_next):
    """Reference for the fused Q-GenX update kernel (one iteration of the
    paper's update rule, given already-averaged dual vectors):

        x_half = x - gamma_cur * v_base
        y_next = y - v_half
        x_next = gamma_next * y_next

    Returns (x_half, y_next, x_next).
    """
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    x_half = x - jnp.float32(gamma_cur) * jnp.asarray(v_base, jnp.float32)
    y_next = y - jnp.asarray(v_half, jnp.float32)
    x_next = jnp.float32(gamma_next) * y_next
    return x_half, y_next, x_next
