//! Single-threaded simulation of the K-processor system — Algorithm 1 with
//! every byte of the wire format exercised, but no thread machinery.
//! Deterministic given the config seed; the workhorse of the benches.

use super::pipeline::Compressor;
use super::schedule::UpdateSchedule;
use crate::algo::{LocalQGenX, QGenX, Sgda};
use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::metrics::{consensus_distance, Recorder, SyncAccounting};
use crate::net::{NetModel, TrafficStats};
use crate::oracle::{build_operator, build_oracle, GapEvaluator, Oracle};
use crate::topo::{build_collective, Collective, LinkTraffic, Topology};
use crate::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Stat-exchange schedule shared by the exact and gossip runners: active
/// only when something adapts (level placement or Huffman tables) and the
/// pipeline is actually quantized.
fn adaptive_schedule(cfg: &ExperimentConfig, comps: &[Compressor]) -> UpdateSchedule {
    if cfg.quant.adapts() && comps[0].is_quantized() {
        UpdateSchedule::new(cfg.quant.update_every.min(10), cfg.quant.update_every)
    } else {
        UpdateSchedule::never()
    }
}

/// Summary scalars shared by the exact and gossip runners — one emission
/// point so cross-topology CSV columns cannot drift apart.
fn emit_summary_scalars(
    rec: &mut Recorder,
    traffic: &TrafficStats,
    links: &LinkTraffic,
    comps: &[Compressor],
    k: usize,
    d: usize,
) {
    rec.set_scalar("total_bits", traffic.bits_sent as f64);
    rec.set_scalar("bits_per_round_per_worker", traffic.bits_per_round_per_worker(k));
    rec.set_scalar("sim_net_time", traffic.sim_net_time);
    rec.set_scalar("compute_time", traffic.compute_time);
    rec.set_scalar("rounds", traffic.rounds as f64);
    rec.set_scalar("level_updates", comps[0].updates() as f64);
    rec.set_scalar("epsilon_q", comps[0].epsilon_q(d));
    rec.set_scalar("wire_links", links.links() as f64);
    rec.set_scalar("max_link_bytes", links.max_link_bytes());
    // Layer-wise pipelines additionally report per-layer scalars
    // (layer_bits/<name>, layer_variance/<name>, layer_levels/<name>);
    // no-op otherwise.
    comps[0].emit_layer_scalars(rec);
}

/// Run one Q-GenX experiment per the config; returns the metric recorder
/// with series `gap`, `dist`, `residual`, `gamma`, `bits_cum`,
/// `sim_time_cum` and summary scalars. The exchange rounds run over the
/// configured [`Topology`]; the config selects one of three runner
/// families:
///
/// * **exact** (this function's body) — per-step dual exchange over an
///   exact topology, the seed's Algorithm 1;
/// * **gossip** (the private `run_gossip`) — inexact topologies: per-step
///   dual exchange averaged over graph neighborhoods, plus `consensus_dist`;
/// * **local** (the private `run_local`) — `local.steps ≥ 2`: private extra-gradient
///   iterations between syncs, quantized model-delta averaging at syncs.
///
/// `local.steps = 1` deliberately does *not* engage the delta-sync
/// machinery: with one local step the algorithm communicates every
/// iteration anyway, and the per-step dual exchange is the trajectory the
/// paper's theorems describe — so it runs the exact (or gossip) path,
/// bit-for-bit identical to the seed.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Recorder> {
    cfg.validate()?;
    let topo = Topology::from_config(&cfg.topo, cfg.workers)?;
    let collective = build_collective(topo, cfg.workers)?;
    if cfg.local.steps > 1 {
        return run_local(cfg, collective);
    }
    if !topo.is_exact() {
        return run_gossip(cfg, collective);
    }
    let op = build_operator(&cfg.problem, cfg.seed)?;
    let d = op.dim();
    let k = cfg.workers;
    let root = Rng::seed_from(cfg.seed);

    // K private oracles + K compression endpoints.
    let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
        .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
        .collect::<Result<_>>()?;
    let mut comps: Vec<Compressor> = (0..k)
        .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
        .collect::<Result<_>>()?;

    let schedule = adaptive_schedule(cfg, &comps);

    let x0 = vec![0.0f32; d];
    let mut state = QGenX::new(cfg.algo.variant, &x0, k, cfg.algo.gamma0, cfg.algo.adaptive_step);

    let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
    let net = NetModel::from_config(&cfg.net);
    let mut traffic = TrafficStats::default();
    let mut links = LinkTraffic::new();
    let mut rec = Recorder::new();

    // Scratch buffers reused across iterations.
    let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
    let mut g_buf = vec![0.0f32; d];

    for t in 1..=cfg.iters {
        // (1) Level-update step: exchange sufficient statistics, pool,
        //     re-optimize — identical on all workers.
        if schedule.is_update(t) {
            let payloads: Vec<Vec<u8>> = comps.iter().map(|c| c.stats_payload()).collect();
            let bits: Vec<u64> = payloads.iter().map(|p| 8 * p.len() as u64).collect();
            traffic.record_allgather(&bits, &net);
            let rank_order: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
            for comp in comps.iter_mut() {
                comp.update_levels(&rank_order)?;
            }
        }

        // (2) Base exchange (variant-dependent).
        let base_vecs: Vec<Vec<f32>> = if let Some(xq) = state.base_query() {
            let t0 = Instant::now();
            let mut bits = Vec::with_capacity(k);
            let mut wires = Vec::with_capacity(k);
            for w in 0..k {
                oracles[w].sample(&xq, &mut g_buf);
                let (bytes, b) = comps[w].compress(&g_buf)?;
                bits.push(b);
                wires.push(bytes);
            }
            // Everyone decodes everyone (we decode once — identical everywhere).
            for w in 0..k {
                comps[w].decompress(&wires[w], &mut decoded[w])?;
            }
            traffic.add_compute(t0.elapsed().as_secs_f64());
            collective.record_round(&bits, &net, &mut traffic);
            links.record(collective.as_ref(), &bits);
            decoded.clone()
        } else {
            Vec::new()
        };

        // (3) Extrapolate.
        let x_half = state.extrapolate(&base_vecs)?;

        // (4) Half-step exchange.
        let t0 = Instant::now();
        let mut bits = Vec::with_capacity(k);
        let mut wires = Vec::with_capacity(k);
        for w in 0..k {
            oracles[w].sample(&x_half, &mut g_buf);
            let (bytes, b) = comps[w].compress(&g_buf)?;
            bits.push(b);
            wires.push(bytes);
        }
        for w in 0..k {
            comps[w].decompress(&wires[w], &mut decoded[w])?;
        }
        traffic.add_compute(t0.elapsed().as_secs_f64());
        collective.record_round(&bits, &net, &mut traffic);
        links.record(collective.as_ref(), &bits);
        state.update(&decoded)?;

        // (5) Evaluation.
        if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
            let avg = state.ergodic_average();
            if let Some(ev) = &gap_eval {
                rec.push("gap", t as f64, ev.gap(op.as_ref(), &avg));
                rec.push("dist", t as f64, ev.dist_to_center(&avg));
            }
            rec.push("residual", t as f64, op.residual(&avg));
            rec.push("gamma", t as f64, state.gamma());
            rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
            rec.push("sim_time_cum", t as f64, traffic.total_time());
            comps[0].record_layer_series(&mut rec, t as f64);
        }
    }

    emit_summary_scalars(&mut rec, &traffic, &links, &comps, k, d);
    Ok(rec)
}

/// Inexact (gossip) runner: `K` genuinely distinct replicas, each
/// averaging dual vectors over its closed graph neighborhood only. The
/// exchange still moves real encoded wire bytes (decode is
/// sender-deterministic, so decoding once per sender is exact); traffic
/// follows the gossip α-β cost. Level updates stay *global* — the decode
/// side of the wire format requires identical codecs on every replica, so
/// the control plane (small, infrequent stat payloads) is pooled full-mesh
/// while the data plane gossips; see `coordinator::mod` docs.
fn run_gossip(cfg: &ExperimentConfig, collective: Arc<dyn Collective>) -> Result<Recorder> {
    let op = build_operator(&cfg.problem, cfg.seed)?;
    let d = op.dim();
    let k = cfg.workers;
    let root = Rng::seed_from(cfg.seed);
    let neigh: Vec<Vec<usize>> = (0..k).map(|r| collective.recipients(r)).collect();

    let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
        .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
        .collect::<Result<_>>()?;
    let mut comps: Vec<Compressor> = (0..k)
        .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
        .collect::<Result<_>>()?;

    let schedule = adaptive_schedule(cfg, &comps);

    let x0 = vec![0.0f32; d];
    let mut states: Vec<QGenX> = neigh
        .iter()
        .map(|n| QGenX::new(cfg.algo.variant, &x0, n.len(), cfg.algo.gamma0, cfg.algo.adaptive_step))
        .collect();

    let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
    let net = NetModel::from_config(&cfg.net);
    let mut traffic = TrafficStats::default();
    let mut links = LinkTraffic::new();
    let mut rec = Recorder::new();
    let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
    let mut g_buf = vec![0.0f32; d];

    // Compress every worker's sample, decode once per sender, and hand each
    // replica its neighborhood view (rank order within the neighborhood).
    let exchange_views = |queries: &[Vec<f32>],
                              oracles: &mut [Box<dyn Oracle>],
                              comps: &mut [Compressor],
                              decoded: &mut [Vec<f32>],
                              traffic: &mut TrafficStats,
                              links: &mut LinkTraffic,
                              g_buf: &mut [f32]|
     -> Result<Vec<Vec<Vec<f32>>>> {
        let t0 = Instant::now();
        let mut bits = Vec::with_capacity(k);
        let mut wires = Vec::with_capacity(k);
        for w in 0..k {
            oracles[w].sample(&queries[w], g_buf);
            let (bytes, b) = comps[w].compress(g_buf)?;
            bits.push(b);
            wires.push(bytes);
        }
        for w in 0..k {
            comps[w].decompress(&wires[w], &mut decoded[w])?;
        }
        traffic.add_compute(t0.elapsed().as_secs_f64());
        collective.record_round(&bits, &net, traffic);
        links.record(collective.as_ref(), &bits);
        Ok(neigh
            .iter()
            .map(|n| n.iter().map(|&w| decoded[w].clone()).collect())
            .collect())
    };

    for t in 1..=cfg.iters {
        // (1) Global (full-mesh) stat pooling keeps all codecs identical.
        if schedule.is_update(t) {
            let payloads: Vec<Vec<u8>> = comps.iter().map(|c| c.stats_payload()).collect();
            let bits: Vec<u64> = payloads.iter().map(|p| 8 * p.len() as u64).collect();
            traffic.record_allgather(&bits, &net);
            let rank_order: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
            for comp in comps.iter_mut() {
                comp.update_levels(&rank_order)?;
            }
        }

        // (2) Base exchange: each replica queries at its *own* iterate.
        let base_views: Vec<Vec<Vec<f32>>> = if states[0].base_query().is_some() {
            let queries: Vec<Vec<f32>> =
                states.iter().map(|s| s.base_query().expect("DE variant")).collect();
            exchange_views(
                &queries,
                &mut oracles,
                &mut comps,
                &mut decoded,
                &mut traffic,
                &mut links,
                &mut g_buf,
            )?
        } else {
            vec![Vec::new(); k]
        };

        // (3) Per-replica extrapolation to its own half-step point.
        let x_halves: Vec<Vec<f32>> = states
            .iter_mut()
            .zip(base_views.iter())
            .map(|(s, v)| s.extrapolate(v))
            .collect::<Result<_>>()?;

        // (4) Half-step exchange at the per-replica half points.
        let half_views = exchange_views(
            &x_halves,
            &mut oracles,
            &mut comps,
            &mut decoded,
            &mut traffic,
            &mut links,
            &mut g_buf,
        )?;
        for (s, v) in states.iter_mut().zip(half_views.iter()) {
            s.update(v)?;
        }

        // (5) Evaluation at the mean ergodic average + consensus tracking.
        if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
            let averages: Vec<Vec<f32>> = states.iter().map(|s| s.ergodic_average()).collect();
            let mut mean_avg = vec![0.0f32; d];
            for a in &averages {
                for (m, &x) in mean_avg.iter_mut().zip(a.iter()) {
                    *m += x / k as f32;
                }
            }
            let iterates: Vec<Vec<f32>> = states.iter().map(|s| s.x_world()).collect();
            if let Some(ev) = &gap_eval {
                rec.push("gap", t as f64, ev.gap(op.as_ref(), &mean_avg));
                rec.push("dist", t as f64, ev.dist_to_center(&mean_avg));
            }
            rec.push("residual", t as f64, op.residual(&mean_avg));
            rec.push("consensus_dist", t as f64, consensus_distance(&iterates));
            rec.push("gamma", t as f64, states[0].gamma());
            rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
            rec.push("sim_time_cum", t as f64, traffic.total_time());
            comps[0].record_layer_series(&mut rec, t as f64);
        }
    }

    // Same scalar set as the exact path (bits_per_round_per_worker is the
    // mesh-normalized figure Theorems 3/4 reference; under gossip it is a
    // comparison yardstick, not a per-edge quantity), plus the consensus
    // scalar only this runner can produce.
    let final_iterates: Vec<Vec<f32>> = states.iter().map(|s| s.x_world()).collect();
    emit_summary_scalars(&mut rec, &traffic, &links, &comps, k, d);
    rec.set_scalar("consensus_dist", consensus_distance(&final_iterates));
    Ok(rec)
}

/// Local-steps runner (`local.steps = H ≥ 2`): each worker runs `H`
/// extra-gradient iterations against its *private* oracle between
/// communication rounds, then the replicas exchange quantized **model
/// deltas** (`X_t − X_sync`, one vector per worker per sync — not one or
/// two duals per iteration) over the configured collective and
/// re-synchronize by averaging the decoded deltas.
///
/// * Exact topologies: every replica averages all `K` decoded deltas, so
///   replicas are bit-identical immediately after every sync; the
///   `sync_drift` series tracks how far they diverged *within* each local
///   segment.
/// * Gossip: each replica averages deltas over its closed neighborhood
///   only — replicas drift persistently, tracked by `consensus_dist` just
///   like [`run_gossip`].
///
/// The control plane (stat pooling for QAda / Huffman refreshes) stays
/// global and fires at the first sync on or after each due point — the
/// early warmup `update_every.min(10)` the per-step runners also use, then
/// every `update_every` — because between syncs there is no wire to carry
/// stats. Note the statistics now describe *delta* coordinates (that is
/// what the codec compresses in this mode), so the refreshed levels/tables
/// fit the actual wire distribution.
fn run_local(cfg: &ExperimentConfig, collective: Arc<dyn Collective>) -> Result<Recorder> {
    let op = build_operator(&cfg.problem, cfg.seed)?;
    let d = op.dim();
    let k = cfg.workers;
    let h = cfg.local.steps;
    let root = Rng::seed_from(cfg.seed);
    let neigh: Vec<Vec<usize>> = (0..k).map(|r| collective.recipients(r)).collect();

    let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
        .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
        .collect::<Result<_>>()?;
    let mut comps: Vec<Compressor> = (0..k)
        .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
        .collect::<Result<_>>()?;

    let adaptive = cfg.quant.adapts() && comps[0].is_quantized();
    let update_every = cfg.quant.update_every;
    // First refresh at the first sync on or after the same early warmup
    // point the per-step runners use (update_every.min(10)) — without it,
    // runs shorter than update_every would never refresh at all.
    let mut next_stat_due = update_every.min(10);

    let x0 = vec![0.0f32; d];
    let mut replicas: Vec<LocalQGenX> = (0..k)
        .map(|_| LocalQGenX::new(cfg.algo.variant, &x0, cfg.algo.gamma0, cfg.algo.adaptive_step))
        .collect();

    let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
    let net = NetModel::from_config(&cfg.net);
    let mut traffic = TrafficStats::default();
    let mut links = LinkTraffic::new();
    let mut rec = Recorder::new();
    let mut sync_acc = SyncAccounting::new();
    let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
    let mut g_buf = vec![0.0f32; d];

    for t in 1..=cfg.iters {
        // (1) One private extra-gradient iteration per replica — no wire.
        let t0 = Instant::now();
        for (rep, oracle) in replicas.iter_mut().zip(oracles.iter_mut()) {
            rep.local_round(oracle.as_mut(), &mut g_buf)?;
        }
        traffic.add_compute(t0.elapsed().as_secs_f64());

        // (2) Synchronization every H local iterations (plus a final sync
        //     so the run always ends on a consensus point).
        if t % h == 0 || t == cfg.iters {
            // (2a) Quantize + exchange the model deltas.
            let t0 = Instant::now();
            let mut bits = Vec::with_capacity(k);
            let mut wires = Vec::with_capacity(k);
            for w in 0..k {
                let delta = replicas[w].delta();
                let (bytes, b) = comps[w].compress(&delta)?;
                bits.push(b);
                wires.push(bytes);
            }
            for w in 0..k {
                comps[w].decompress(&wires[w], &mut decoded[w])?;
            }
            traffic.add_compute(t0.elapsed().as_secs_f64());
            let bits_before = traffic.bits_sent;
            collective.record_round(&bits, &net, &mut traffic);
            links.record(collective.as_ref(), &bits);

            // (2b) Pre-averaging drift + per-sync bit accounting.
            let iterates: Vec<Vec<f32>> = replicas.iter().map(|r| r.x_world()).collect();
            sync_acc.record(
                &mut rec,
                t,
                consensus_distance(&iterates),
                traffic.bits_sent - bits_before,
            );

            // (2c) Resync each replica onto its neighborhood-averaged delta
            //      (all K under exact topologies).
            for (rep, n) in replicas.iter_mut().zip(neigh.iter()) {
                let mut mean = vec![0.0f32; d];
                for &w in n {
                    for (m, &x) in mean.iter_mut().zip(decoded[w].iter()) {
                        *m += x / n.len() as f32;
                    }
                }
                rep.resync(&mean)?;
            }

            // (2d) Control plane: pooled stat exchange at the first sync on
            //      or after each due point (always full-mesh — the wire
            //      format needs identical codecs everywhere).
            if adaptive && update_every != 0 && t >= next_stat_due {
                let payloads: Vec<Vec<u8>> = comps.iter().map(|c| c.stats_payload()).collect();
                let stat_bits: Vec<u64> = payloads.iter().map(|p| 8 * p.len() as u64).collect();
                traffic.record_allgather(&stat_bits, &net);
                let rank_order: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
                for comp in comps.iter_mut() {
                    comp.update_levels(&rank_order)?;
                }
                next_stat_due = t + update_every;
            }
        }

        // (3) Evaluation at the mean ergodic average across replicas.
        if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
            let mut mean_avg = vec![0.0f32; d];
            for rep in &replicas {
                for (m, &x) in mean_avg.iter_mut().zip(rep.ergodic_average().iter()) {
                    *m += x / k as f32;
                }
            }
            let iterates: Vec<Vec<f32>> = replicas.iter().map(|r| r.x_world()).collect();
            if let Some(ev) = &gap_eval {
                rec.push("gap", t as f64, ev.gap(op.as_ref(), &mean_avg));
                rec.push("dist", t as f64, ev.dist_to_center(&mean_avg));
            }
            rec.push("residual", t as f64, op.residual(&mean_avg));
            rec.push("consensus_dist", t as f64, consensus_distance(&iterates));
            rec.push("gamma", t as f64, replicas[0].gamma());
            rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
            rec.push("sim_time_cum", t as f64, traffic.total_time());
            comps[0].record_layer_series(&mut rec, t as f64);
        }
    }

    // Final consensus over the *sync bases*: the run ends on a sync, and
    // the consensus point is computed by identical arithmetic on every
    // replica — exactly 0 under exact topologies (the raw iterates can sit
    // an origin-shift rounding ulp off it; see `algo::local` docs).
    let final_bases: Vec<Vec<f32>> = replicas.iter().map(|r| r.sync_base().to_vec()).collect();
    emit_summary_scalars(&mut rec, &traffic, &links, &comps, k, d);
    sync_acc.emit_scalars(&mut rec);
    rec.set_scalar("local_steps", h as f64);
    rec.set_scalar("consensus_dist", consensus_distance(&final_bases));
    Ok(rec)
}

/// QSGDA baseline (Beznosikov et al. 2022): quantized SGDA with γ_t = γ₀/√t,
/// same oracles/compressors/network — only the update rule differs
/// (no extrapolation, no adaptive step). The Figure-4 comparator.
pub fn run_qsgda_baseline(cfg: &ExperimentConfig) -> Result<Recorder> {
    cfg.validate()?;
    let op = build_operator(&cfg.problem, cfg.seed)?;
    let d = op.dim();
    let k = cfg.workers;
    let root = Rng::seed_from(cfg.seed);
    let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
        .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
        .collect::<Result<_>>()?;
    let mut comps: Vec<Compressor> = (0..k)
        .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
        .collect::<Result<_>>()?;
    let x0 = vec![0.0f32; d];
    let mut sgda = Sgda::new(&x0, cfg.algo.gamma0, true);
    let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
    let net = NetModel::from_config(&cfg.net);
    let mut traffic = TrafficStats::default();
    let mut rec = Recorder::new();
    let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
    let mut g_buf = vec![0.0f32; d];

    for t in 1..=cfg.iters {
        let xq = sgda.query();
        let mut bits = Vec::with_capacity(k);
        let mut wires = Vec::with_capacity(k);
        for w in 0..k {
            oracles[w].sample(&xq, &mut g_buf);
            let (bytes, b) = comps[w].compress(&g_buf)?;
            bits.push(b);
            wires.push(bytes);
        }
        for w in 0..k {
            comps[w].decompress(&wires[w], &mut decoded[w])?;
        }
        traffic.record_allgather(&bits, &net);
        sgda.update(&decoded);
        if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
            let avg = sgda.ergodic_average();
            if let Some(ev) = &gap_eval {
                rec.push("gap", t as f64, ev.gap(op.as_ref(), &avg));
                rec.push("dist", t as f64, ev.dist_to_center(&avg));
                rec.push("dist_last", t as f64, ev.dist_to_center(sgda.x()));
            }
            rec.push("residual", t as f64, op.residual(&avg));
            rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
        }
    }
    rec.set_scalar("total_bits", traffic.bits_sent as f64);
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LevelScheme, QuantMode, Variant};

    fn base_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 3;
        cfg.iters = 400;
        cfg.eval_every = 100;
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 16;
        cfg.problem.noise = "absolute".into();
        cfg.problem.sigma = 0.3;
        cfg.quant.update_every = 100;
        cfg
    }

    #[test]
    fn qgenx_converges_quantized_absolute_noise() {
        let cfg = base_cfg();
        let rec = run_experiment(&cfg).unwrap();
        let gaps = rec.get("gap").unwrap();
        let first = gaps.points.first().unwrap().1;
        let last = gaps.last().unwrap();
        assert!(last < first, "gap should shrink: {first} -> {last}");
        assert!(rec.scalar("total_bits").unwrap() > 0.0);
        assert!(rec.scalar("level_updates").unwrap() >= 1.0);
    }

    #[test]
    fn fp32_and_quantized_converge_similarly_but_quantized_sends_fewer_bits() {
        let mut cfg = base_cfg();
        cfg.iters = 600;
        let rec_q = run_experiment(&cfg).unwrap();
        cfg.quant.mode = QuantMode::Fp32;
        let rec_f = run_experiment(&cfg).unwrap();
        let bits_q = rec_q.scalar("total_bits").unwrap();
        let bits_f = rec_f.scalar("total_bits").unwrap();
        assert!(bits_q < bits_f / 3.0, "quantized {bits_q} vs fp32 {bits_f}");
        // Both reach a small gap.
        let gq = rec_q.get("gap").unwrap().last().unwrap();
        let gf = rec_f.get("gap").unwrap().last().unwrap();
        assert!(gq < 1.0 && gf < 1.0, "gq={gq} gf={gf}");
    }

    #[test]
    fn all_variants_run_and_converge() {
        for v in [Variant::DualAveraging, Variant::DualExtrapolation, Variant::OptimisticDualAveraging] {
            let mut cfg = base_cfg();
            cfg.algo.variant = v;
            cfg.iters = 500;
            let rec = run_experiment(&cfg).unwrap();
            let last = rec.get("gap").unwrap().last().unwrap();
            assert!(last.is_finite(), "variant {v:?} gap {last}");
        }
    }

    #[test]
    fn da_and_optda_send_half_the_rounds_of_de() {
        let mut cfg = base_cfg();
        cfg.quant.scheme = LevelScheme::Uniform; // no stat-exchange rounds
        cfg.algo.variant = Variant::DualExtrapolation;
        let rec_de = run_experiment(&cfg).unwrap();
        cfg.algo.variant = Variant::OptimisticDualAveraging;
        let rec_opt = run_experiment(&cfg).unwrap();
        let r_de = rec_de.scalar("rounds").unwrap();
        let r_opt = rec_opt.scalar("rounds").unwrap();
        assert!((r_de / r_opt - 2.0).abs() < 0.01, "de {r_de} opt {r_opt}");
    }

    #[test]
    fn more_workers_reduce_final_error_under_absolute_noise() {
        // Theorem 3's 1/sqrt(K): K=8 should beat K=1 on the same budget.
        // Average over seeds — a single run's final gap is itself noisy.
        let mut d1 = 0.0;
        let mut d8 = 0.0;
        for seed in 0..5u64 {
            let mut cfg = base_cfg();
            cfg.seed = 1000 + seed;
            cfg.iters = 1500;
            cfg.problem.sigma = 2.0;
            cfg.algo.gamma0 = 0.3;
            cfg.workers = 1;
            d1 += run_experiment(&cfg).unwrap().get("dist").unwrap().last().unwrap();
            cfg.workers = 8;
            d8 += run_experiment(&cfg).unwrap().get("dist").unwrap().last().unwrap();
        }
        assert!(d8 < d1 * 0.8, "K=8 dist {d8} should beat K=1 dist {d1}");
    }

    #[test]
    fn qsgda_baseline_runs() {
        let mut cfg = base_cfg();
        cfg.iters = 300;
        let rec = run_qsgda_baseline(&cfg).unwrap();
        assert!(rec.get("dist").unwrap().last().unwrap().is_finite());
    }

    #[test]
    fn exact_topologies_share_one_trajectory_but_not_one_cost() {
        // Star/ring/hierarchical aggregate the same rank-order mean the mesh
        // broadcasts, so the iterate trajectory is bit-identical; only the
        // modeled traffic and time differ.
        let mut cfg = base_cfg();
        cfg.workers = 8;
        cfg.iters = 120;
        cfg.eval_every = 40;
        let mesh = run_experiment(&cfg).unwrap();
        for kind in ["star", "ring", "hierarchical"] {
            cfg.topo.kind = kind.into();
            let rec = run_experiment(&cfg).unwrap();
            assert_eq!(
                rec.get("gap").unwrap().ys(),
                mesh.get("gap").unwrap().ys(),
                "{kind} trajectory must match full mesh bit-for-bit"
            );
            assert!(
                rec.scalar("total_bits").unwrap() < mesh.scalar("total_bits").unwrap(),
                "{kind} must aggregate below mesh traffic"
            );
            assert!(rec.scalar("max_link_bytes").unwrap() > 0.0);
        }
    }

    #[test]
    fn gossip_runs_and_tracks_consensus() {
        let mut cfg = base_cfg();
        cfg.workers = 8;
        cfg.iters = 200;
        cfg.eval_every = 50;
        cfg.topo.kind = "gossip".into();
        cfg.topo.degree = 3;
        let rec = run_experiment(&cfg).unwrap();
        let cons = rec.get("consensus_dist").unwrap();
        assert!(cons.points.iter().all(|(_, y)| y.is_finite()));
        assert!(rec.scalar("consensus_dist").unwrap().is_finite());
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
        // neighborhood exchange puts fewer bits on the wire than the mesh
        cfg.topo.kind = "full-mesh".into();
        let mesh = run_experiment(&cfg).unwrap();
        assert!(rec.scalar("total_bits").unwrap() < mesh.scalar("total_bits").unwrap());
        // replicas genuinely diverge under noise
        assert!(rec.scalar("consensus_dist").unwrap() > 0.0);
    }

    #[test]
    fn huffman_with_fixed_levels_actually_refreshes_mid_run() {
        // Regression for the silent Huffman-refresh no-op: with uniform
        // (fixed) levels and a Huffman codec, the scheduled stat rounds
        // used to exchange empty payloads — the pooled stats were empty,
        // update_levels bailed out early, and `level_updates` stayed 0
        // even though the run paid the stat-round network cost.
        let mut cfg = base_cfg();
        cfg.quant.scheme = LevelScheme::Uniform;
        cfg.quant.codec = crate::coding::SymbolCodec::Huffman;
        cfg.iters = 300;
        let rec = run_experiment(&cfg).unwrap();
        assert!(
            rec.scalar("level_updates").unwrap() >= 1.0,
            "fixed-levels Huffman run must perform at least one real codec refresh"
        );
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
    }

    #[test]
    fn local_steps_one_is_bit_identical_to_seed_exact_runner() {
        // `local.steps = 1` must not engage the delta-sync machinery: the
        // run is the seed per-step dual exchange, bit-for-bit, for every
        // variant.
        for v in [Variant::DualAveraging, Variant::DualExtrapolation, Variant::OptimisticDualAveraging]
        {
            let mut cfg = base_cfg();
            cfg.algo.variant = v;
            cfg.iters = 200;
            let seed_rec = run_experiment(&cfg).unwrap();
            cfg.local.steps = 1; // explicit, same meaning as the default
            let local_rec = run_experiment(&cfg).unwrap();
            assert_eq!(
                seed_rec.get("gap").unwrap().ys(),
                local_rec.get("gap").unwrap().ys(),
                "variant {v:?} trajectory must match the seed bit-for-bit"
            );
            assert_eq!(
                seed_rec.scalar("total_bits"),
                local_rec.scalar("total_bits"),
                "variant {v:?} wire bits must match the seed exactly"
            );
            assert!(local_rec.scalar("syncs").is_none(), "no delta-sync path at H = 1");
        }
    }

    #[test]
    fn local_steps_converge_and_cut_wire_bits() {
        let mut cfg = base_cfg();
        cfg.iters = 600;
        cfg.eval_every = 150;
        let exact = run_experiment(&cfg).unwrap();
        cfg.local.steps = 4;
        let local = run_experiment(&cfg).unwrap();

        // Still converges on the MonotoneQuadratic.
        let gaps = local.get("gap").unwrap();
        let first = gaps.points.first().unwrap().1;
        let last = gaps.last().unwrap();
        assert!(last < first, "local-steps gap should shrink: {first} -> {last}");
        assert!(last < 1.0, "local-steps final gap too large: {last}");

        // Communicating every 4th iteration strictly cuts total wire bits.
        let bits_local = local.scalar("total_bits").unwrap();
        let bits_exact = exact.scalar("total_bits").unwrap();
        assert!(
            bits_local < bits_exact,
            "H = 4 must send fewer bits: {bits_local} vs {bits_exact}"
        );

        // Sync accounting: 600 / 4 syncs, drift accumulates between syncs,
        // and the final sync leaves the replicas bit-identical.
        assert_eq!(local.scalar("syncs"), Some(150.0));
        assert_eq!(local.scalar("local_steps"), Some(4.0));
        assert!(local.scalar("bits_per_sync").unwrap() > 0.0);
        let drift = local.get("sync_drift").unwrap();
        assert!(drift.points.iter().all(|(_, y)| y.is_finite()));
        assert!(
            drift.ys().iter().any(|&y| y > 0.0),
            "private noisy oracles must produce nonzero intra-segment drift"
        );
        assert_eq!(
            local.scalar("consensus_dist"),
            Some(0.0),
            "exact topology: replicas must be bit-identical after the final sync"
        );
    }

    #[test]
    fn local_steps_refresh_codecs_even_on_short_runs() {
        // Regression: the local stat schedule must keep the per-step
        // runners' early warmup — a run shorter than update_every still
        // performs a real refresh at the first sync past the warmup point.
        let mut cfg = base_cfg();
        cfg.iters = 60; // < update_every (100)
        cfg.local.steps = 4;
        let rec = run_experiment(&cfg).unwrap();
        assert!(
            rec.scalar("level_updates").unwrap() >= 1.0,
            "short local runs must still refresh the codec"
        );
    }

    #[test]
    fn local_steps_compose_with_gossip() {
        let mut cfg = base_cfg();
        cfg.workers = 8;
        cfg.iters = 200;
        cfg.eval_every = 50;
        cfg.local.steps = 5;
        cfg.topo.kind = "gossip".into();
        cfg.topo.degree = 3;
        let rec = run_experiment(&cfg).unwrap();
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
        assert_eq!(rec.scalar("syncs"), Some(40.0));
        // neighborhood averaging never reaches full consensus
        assert!(rec.scalar("consensus_dist").unwrap() > 0.0);
    }

    #[test]
    fn single_layer_map_reproduces_all_three_runners_bit_for_bit() {
        // The Q-GenX-LW acceptance contract: a one-layer [quant.layers]
        // map runs the seed machinery — identical trajectories AND
        // identical wire accounting — for the exact, gossip, and local
        // runner families.
        for (kind, h) in [("full-mesh", 1usize), ("gossip", 1), ("full-mesh", 4)] {
            let mut cfg = base_cfg();
            cfg.workers = 8;
            cfg.iters = 160;
            cfg.eval_every = 40;
            cfg.topo.kind = kind.into();
            cfg.local.steps = h;
            let baseline = run_experiment(&cfg).unwrap();
            cfg.quant.layers.names = vec!["all".into()];
            let layered = run_experiment(&cfg).unwrap();
            assert_eq!(
                baseline.get("gap").unwrap().ys(),
                layered.get("gap").unwrap().ys(),
                "{kind}/H={h}: trajectory must match bit-for-bit"
            );
            assert_eq!(
                baseline.scalar("total_bits"),
                layered.scalar("total_bits"),
                "{kind}/H={h}: wire bits must match exactly"
            );
            assert!(
                layered.scalar("layers").is_none(),
                "one layer must not surface layer-wise metrics"
            );
        }
    }

    #[test]
    fn layerwise_runner_end_to_end_with_budget() {
        let mut cfg = base_cfg();
        cfg.problem.dim = 96;
        cfg.iters = 300;
        cfg.quant.bucket_size = 32;
        cfg.quant.scheme = LevelScheme::Uniform;
        cfg.quant.codec = crate::coding::SymbolCodec::Fixed;
        cfg.quant.layers.names = vec!["embed".into(), "body".into(), "head".into()];
        cfg.quant.layers.bounds = vec![32, 64];
        cfg.quant.layers.budget = 4.0;
        let rec = run_experiment(&cfg).unwrap();
        // Converges, refreshes (the budget forces stat rounds even though
        // scheme/codec are static), and surfaces per-layer accounting.
        let gaps = rec.get("gap").unwrap();
        assert!(gaps.last().unwrap() < gaps.points.first().unwrap().1);
        assert!(rec.scalar("level_updates").unwrap() >= 1.0);
        assert_eq!(rec.scalar("layers"), Some(3.0));
        let mut layer_sum = 0.0;
        for name in ["embed", "body", "head"] {
            let bits = rec.scalar(&format!("layer_bits/{name}")).unwrap();
            assert!(bits > 0.0, "{name} must put bits on the wire");
            layer_sum += bits;
            assert!(rec.scalar(&format!("layer_variance/{name}")).unwrap() > 0.0);
            assert!(rec.scalar(&format!("layer_levels/{name}")).unwrap() >= 1.0);
            let series = rec.get(&format!("layer_bits/{name}")).unwrap();
            assert!(series.len() >= 2 && series.last().unwrap() > 0.0);
        }
        // Per-layer payload bits are one worker's share (before collective
        // amplification and framing), so they undercount the global total.
        assert!(layer_sum < rec.scalar("total_bits").unwrap());
        // epsilon_q scalar is the dimension-weighted blend — finite, > 0.
        let eps = rec.scalar("epsilon_q").unwrap();
        assert!(eps.is_finite() && eps > 0.0);
    }

    #[test]
    fn layerwise_composes_with_gossip_and_local_steps() {
        let mut cfg = base_cfg();
        cfg.workers = 8;
        cfg.problem.dim = 48;
        cfg.iters = 200;
        cfg.eval_every = 50;
        cfg.quant.bucket_size = 16;
        cfg.quant.layers.names = vec!["lo".into(), "hi".into()];
        cfg.quant.layers.bounds = vec![16];
        cfg.topo.kind = "gossip".into();
        cfg.topo.degree = 3;
        let rec = run_experiment(&cfg).unwrap();
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
        assert_eq!(rec.scalar("layers"), Some(2.0));
        assert!(rec.scalar("consensus_dist").unwrap() > 0.0);

        cfg.topo.kind = "full-mesh".into();
        cfg.local.steps = 4;
        let rec = run_experiment(&cfg).unwrap();
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
        assert_eq!(rec.scalar("layers"), Some(2.0));
        assert_eq!(rec.scalar("syncs"), Some(50.0));
        assert_eq!(
            rec.scalar("consensus_dist"),
            Some(0.0),
            "exact topology: layer-wise replicas must re-sync exactly"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = base_cfg();
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(
            a.get("gap").unwrap().ys(),
            b.get("gap").unwrap().ys(),
            "inline runner must be deterministic"
        );
    }
}
