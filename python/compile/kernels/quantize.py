"""L1 Pallas kernel: stochastic level quantization (paper Definition 1).

This is the paper's compute hot-spot expressed for the TPU memory
hierarchy. Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA
reference (torch_cgx) tiles over threadblocks with the bucket in shared
memory; here the same schedule is expressed with a `BlockSpec` grid —
one program instance per block of `BLOCK` coordinates streamed
HBM -> VMEM, the (tiny) level table replicated into VMEM for every
instance, and the per-bucket norm delivered as a scalar operand. The bin
search is branchless (broadcast compare + row sum => one (BLOCK, L) VPU
op), so the kernel is a single pass over `v` with no gather.

MUST run with interpret=True on CPU PJRT: real TPU lowering emits a Mosaic
custom-call the CPU plugin cannot execute. Correctness is pinned to
`ref.ref_quantize` (bit-identical math) by python/tests/test_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size over the coordinate axis. VMEM budget per instance:
# v + uniforms + out (3 * BLOCK * 4B) + levels (L * 4B) ~ 48 KiB at
# BLOCK = 4096, L <= 256 — comfortably inside a TPU core's ~16 MiB VMEM
# with generous double-buffering headroom.
BLOCK = 4096


def _quantize_kernel(norm_ref, v_ref, u_ref, levels_ref, out_ref):
    """One block: quantize BLOCK coordinates against the full level table."""
    v = v_ref[...]  # (BLOCK,)
    u_rand = u_ref[...]  # (BLOCK,)
    levels = levels_ref[...]  # (L,)
    norm = norm_ref[0]

    inv = jnp.where(norm > 0.0, 1.0 / norm, 0.0)
    mag = jnp.minimum(jnp.abs(v) * inv, 1.0)

    # Branchless bin search: tau = #{interior levels <= mag}, computed as a
    # (BLOCK, L-2) compare + row-sum — VPU-friendly, no gather.
    interior = levels[1:-1]
    tau = jnp.sum(mag[:, None] >= interior[None, :], axis=1).astype(jnp.int32)

    lo = levels[tau]
    hi = levels[tau + 1]
    xi = (mag - lo) / (hi - lo)
    up = (u_rand < xi).astype(jnp.int32)
    sym = tau + up
    quantized = jnp.sign(v) * norm * levels[sym]
    out_ref[...] = jnp.where(norm > 0.0, quantized, jnp.zeros_like(v))


@functools.partial(jax.jit, static_argnames=("block",))
def quantize(v, levels, uniforms, norm, *, block=BLOCK):
    """Quantize a (padded) vector with the Pallas kernel.

    Args:
      v: f32[d] with d a multiple of ``block`` (pad with zeros if needed —
        zero coordinates quantize to zero and are wire-free anyway).
      levels: f32[L] full level sequence (0 ... 1).
      uniforms: f32[d] U[0,1) randomness.
      norm: f32[1] scalar norm of the *whole* vector (single bucket; the
        L2 wrapper loops buckets by calling this per bucket slice or maps
        over a (nb, bucket) reshape).

    Returns:
      f32[d] dequantized reconstruction.
    """
    d = v.shape[0]
    if d % block != 0:
        raise ValueError(f"d={d} must be a multiple of block={block}")
    grid = (d // block,)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # norm: replicated scalar
            pl.BlockSpec((block,), lambda i: (i,)),  # v: streamed blocks
            pl.BlockSpec((block,), lambda i: (i,)),  # uniforms: streamed
            pl.BlockSpec(levels.shape, lambda i: (0,)),  # levels: replicated
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(norm, v, uniforms, levels)


def quantize_bucketed(v, levels, uniforms, bucket_size):
    """Bucketed quantization: split ``v`` into ``bucket_size`` buckets, each
    with its own L2 norm (torch_cgx-style; what the Rust wire path does).

    Pure-jnp orchestration around the kernel: norms are computed at the L2
    layer, the kernel is vmapped over buckets.
    """
    d = v.shape[0]
    if d % bucket_size != 0:
        raise ValueError(f"d={d} must be a multiple of bucket_size={bucket_size}")
    nb = d // bucket_size
    vb = v.reshape(nb, bucket_size)
    ub = uniforms.reshape(nb, bucket_size)
    norms = jnp.linalg.norm(vb, axis=1, keepdims=True)  # (nb, 1)

    def one_bucket(vi, ui, ni):
        block = min(BLOCK, bucket_size)
        return quantize(vi, levels, ui, ni, block=block)

    out = jax.vmap(one_bucket, in_axes=(0, 0, 0))(vb, ub, norms)
    return out.reshape(d)
