//! The JSONL event sink: one [`Json`] object per line, `manifest` first,
//! then `step` events, closed by a `summary` (schema:
//! `docs/OBSERVABILITY.md`, version [`crate::telemetry::TELEMETRY_SCHEMA`]).
//!
//! Writes are buffered and best-effort: a failed write marks the sink dead
//! and reports once to stderr instead of aborting a multi-hour run over a
//! full disk. The run itself never depends on sink health — telemetry is
//! observation, not state.

use crate::runtime::json::Json;
use std::io::Write;

/// A line-oriented JSON event stream on disk.
pub struct JsonlSink {
    out: std::io::BufWriter<std::fs::File>,
    path: String,
    dead: bool,
}

impl JsonlSink {
    /// Create/truncate the stream at `path` (parent dirs created) and
    /// write `manifest` as its first event.
    pub fn create(path: &str, manifest: &Json) -> std::io::Result<Self> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let file = std::fs::File::create(path)?;
        let mut sink =
            JsonlSink { out: std::io::BufWriter::new(file), path: path.to_string(), dead: false };
        sink.write(manifest);
        Ok(sink)
    }

    /// Path this sink writes to.
    pub fn path(&self) -> &str {
        &self.path
    }

    /// Append one event line (best-effort; see module docs).
    pub fn write(&mut self, event: &Json) {
        if self.dead {
            return;
        }
        let mut line = event.dump();
        line.push('\n');
        if let Err(e) = self.out.write_all(line.as_bytes()) {
            eprintln!("telemetry: dropping JSONL sink {}: {e}", self.path);
            self.dead = true;
        }
    }

    /// Flush buffered events to disk.
    pub fn flush(&mut self) {
        if !self.dead {
            let _ = self.out.flush();
        }
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_one_parsable_event_per_line_manifest_first() {
        let path = std::env::temp_dir().join("qgenx_telemetry_sink_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        {
            let manifest = Json::obj([
                ("event", Json::Str("manifest".into())),
                ("schema", Json::Num(1.0)),
            ]);
            let mut s = JsonlSink::create(&path, &manifest).unwrap();
            assert_eq!(s.path(), path);
            s.write(&Json::obj([("event", Json::Str("step".into())), ("t", Json::Num(1.0))]));
            s.write(&Json::obj([("event", Json::Str("summary".into()))]));
            // drop flushes
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let events: Vec<String> = lines
            .iter()
            .map(|l| {
                Json::parse(l).unwrap().get("event").unwrap().as_str().unwrap().to_string()
            })
            .collect();
        assert_eq!(events, ["manifest", "step", "summary"]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn create_makes_parent_dirs_and_truncates() {
        let dir = std::env::temp_dir().join("qgenx_telemetry_sink_dir");
        let path = dir.join("sub/run.jsonl");
        let path = path.to_str().unwrap().to_string();
        for _ in 0..2 {
            let mut s = JsonlSink::create(&path, &Json::Null).unwrap();
            s.flush();
        }
        // second create truncated: exactly one manifest line
        assert_eq!(std::fs::read_to_string(&path).unwrap().lines().count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
