//! α-β round cost per topology.
//!
//! Shared model assumptions (documented once, used by every formula):
//!
//! * Every node has one full-duplex NIC of bandwidth `β` bytes/s; a node's
//!   concurrent sends serialize over its own NIC while receives overlap.
//! * Each sequential *phase* of a collective pays the latency `α` once
//!   (messages inside a phase pipeline).
//! * The half-step exchange is semantically an **allreduce**: Algorithm 1
//!   only consumes the rank-order mean of the decoded dual vectors, so
//!   aggregation-capable topologies forward *aggregates* instead of raw
//!   payload sets. An aggregate message re-encoded through `CODE ∘ Q` is
//!   modeled at the size of the largest leaf payload, plus
//!   [`AGG_PIGGYBACK_BYTES`] for the piggybacked per-worker step-size
//!   statistic `‖V̂_{k,t} − V̂_{k,t+1/2}‖²` (one f64 — the adaptive
//!   step-size needs the per-worker sum, which aggregation would otherwise
//!   destroy).
//! * The full mesh cannot aggregate (every node needs to *form* the mean
//!   itself), so it pays `(K−1)·b` per NIC — the seed's
//!   [`NetModel::allgather_time`], unchanged. This is what ring / star /
//!   hierarchical beat at scale: their per-NIC traffic is `O(b)` instead of
//!   `O(K·b)`.
//!
//! Exact wire bits are preserved where leaves travel unaggregated (mesh
//! leaf broadcasts, hierarchical up-links, gossip edges); aggregate
//! messages are accounted at their modeled byte size.

use crate::net::{bits_to_bytes, NetModel};

/// Bytes added to every aggregate message for the piggybacked per-worker
/// step-size statistic (one f64).
pub const AGG_PIGGYBACK_BYTES: usize = 8;

/// Modeled cost of one synchronous exchange round.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundCost {
    /// Simulated wall-clock seconds (α-β model).
    pub secs: f64,
    /// Total payload bits put on the wire by all senders.
    pub wire_bits: u64,
    /// Point-to-point messages.
    pub messages: u64,
}

/// Size (bytes) of an aggregate message: largest leaf payload re-encoded,
/// plus the piggybacked step-size scalar.
fn aggregate_bytes(bits_each: &[u64]) -> usize {
    let max_b = bits_each.iter().map(|&b| bits_to_bytes(b)).max().unwrap_or(0);
    max_b + AGG_PIGGYBACK_BYTES
}

/// Full mesh: flat synchronous allgather, no aggregation possible. Every
/// node serializes `K−1` copies of its payload over its NIC:
/// `max_i (α + (K−1)·b_i/β)`. Bit-identical accounting to the seed's
/// `TrafficStats::record_allgather`.
///
/// This runs on *every* loopback data round, so it is allocation-free: the
/// fold below is `NetModel::allgather_time` inlined term-by-term (same
/// per-sender expression, same `fold(0.0, f64::max)` order — bit-identical
/// `secs`) without materializing the intermediate byte vector.
pub fn full_mesh(model: &NetModel, bits_each: &[u64]) -> RoundCost {
    let k = bits_each.len();
    if k <= 1 {
        return RoundCost::default();
    }
    let secs = bits_each
        .iter()
        .map(|&b| {
            model.latency_s + ((k - 1) * bits_to_bytes(b)) as f64 / model.bandwidth_bps
        })
        .fold(0.0, f64::max);
    RoundCost {
        secs,
        wire_bits: bits_each.iter().map(|&b| b * (k - 1) as u64).sum(),
        messages: (k * (k - 1)) as u64,
    }
}

/// Ring allreduce (reduce-scatter + allgather of aggregate chunks):
/// `2(K−1)` pipeline steps, each moving one `b̄/K` chunk per node:
/// `2(K−1)·(α + (b̄/K)/β)`. Per-NIC traffic `≈ 2b̄` — independent of `K`.
pub fn ring(model: &NetModel, bits_each: &[u64]) -> RoundCost {
    let k = bits_each.len();
    if k <= 1 {
        return RoundCost::default();
    }
    let agg = aggregate_bytes(bits_each) as f64;
    let chunk = agg / k as f64;
    let steps = 2 * (k - 1);
    RoundCost {
        secs: steps as f64 * (model.latency_s + chunk / model.bandwidth_bps),
        // every node sends `steps` chunks: k · steps · (agg/k) = steps · agg
        wire_bits: (8.0 * steps as f64 * agg).round() as u64,
        messages: (k * steps) as u64,
    }
}

/// Star as a *sharded* parameter server (the production deployment: each
/// worker serves `1/K` of the coordinates). Push: every worker sends its
/// `K−1` foreign shard slices; pull: every shard server returns its
/// aggregated shard to `K−1` workers. Two phases:
/// `2α + ((K−1)/K)·(b_max + b̄)/β`.
pub fn star(model: &NetModel, bits_each: &[u64]) -> RoundCost {
    let k = bits_each.len();
    if k <= 1 {
        return RoundCost::default();
    }
    let agg = aggregate_bytes(bits_each) as f64;
    let frac = (k - 1) as f64 / k as f64;
    let max_b = bits_each.iter().map(|&b| bits_to_bytes(b)).max().unwrap_or(0) as f64;
    let push_secs = model.latency_s + frac * max_b / model.bandwidth_bps;
    let pull_secs = model.latency_s + frac * agg / model.bandwidth_bps;
    let push_bytes: f64 = bits_each.iter().map(|&b| bits_to_bytes(b) as f64 * frac).sum();
    let pull_bytes = (k - 1) as f64 * agg; // k servers × (k−1) pulls × agg/k
    RoundCost {
        secs: push_secs + pull_secs,
        wire_bits: (8.0 * (push_bytes + pull_bytes)).round() as u64,
        messages: 2 * (k * (k - 1)) as u64,
    }
}

/// Centralized single-leader star — the seed's test-only
/// `NetModel::star_round_time`, absorbed here verbatim: gather `K−1`
/// payloads serially into the leader, then the leader broadcasts the
/// aggregate to `K−1` members over its own NIC. Kept as the reference
/// model for an *unsharded* parameter server (always ≥ the sharded
/// [`star`], and ≥ the mesh for equal payloads — which is why production
/// parameter servers shard).
pub fn centralized_star_time(model: &NetModel, bytes: &[usize]) -> f64 {
    let k = bytes.len();
    if k <= 1 {
        return 0.0;
    }
    let total: usize = bytes.iter().sum();
    let max_b = *bytes.iter().max().unwrap();
    2.0 * model.latency_s
        + (total - max_b.min(total)) as f64 / model.bandwidth_bps
        + ((k - 1) * max_b) as f64 / model.bandwidth_bps
}

/// Two-level hierarchical reduce-broadcast over contiguous groups
/// (`groups` groups of `⌈K/G⌉` ranks, first rank of each group leads):
///
/// 1. *up* — members send raw payloads to their leader (exact bits), which
///    aggregates; leader NICs receive in parallel across groups:
///    `α + max_g(Σ_{members} b_i)/β`;
/// 2. *across* — the `G` leaders allgather their aggregates:
///    `α + (G−1)·b̄/β`;
/// 3. *down* — each leader serializes the global aggregate to its members:
///    `α + (m_max−1)·b̄/β`.
pub fn hierarchical(model: &NetModel, bits_each: &[u64], groups: usize) -> RoundCost {
    let k = bits_each.len();
    if k <= 1 {
        return RoundCost::default();
    }
    let agg = aggregate_bytes(bits_each) as f64;
    let mut up_bits: u64 = 0;
    let mut up_max_bytes = 0usize;
    let mut max_members = 0usize;
    let mut n_groups = 0usize;
    for r in super::group_ranges(k, groups) {
        let members = r.start + 1..r.end;
        let member_bytes: usize =
            bits_each[members.clone()].iter().map(|&b| bits_to_bytes(b)).sum();
        up_bits += bits_each[members.clone()].iter().sum::<u64>();
        up_max_bytes = up_max_bytes.max(member_bytes);
        max_members = max_members.max(members.len());
        n_groups += 1;
    }
    let beta = model.bandwidth_bps;
    let up_secs = model.latency_s + up_max_bytes as f64 / beta;
    let across_secs = if n_groups > 1 {
        model.latency_s + (n_groups - 1) as f64 * agg / beta
    } else {
        0.0
    };
    let down_secs = if max_members > 0 {
        model.latency_s + max_members as f64 * agg / beta
    } else {
        0.0
    };
    let members_total = (k - n_groups) as f64;
    let across_bytes = (n_groups * n_groups.saturating_sub(1)) as f64 * agg;
    let down_bytes = members_total * agg;
    RoundCost {
        secs: up_secs + across_secs + down_secs,
        wire_bits: up_bits + (8.0 * (across_bytes + down_bytes)).round() as u64,
        messages: ((k - n_groups) + n_groups * n_groups.saturating_sub(1) + (k - n_groups))
            as u64,
    }
}

/// Gossip round over a fixed undirected graph: node `i` serializes its
/// payload to each of its `deg_i` neighbors: `max_i (α + deg_i·b_i/β)`.
/// Exact bits on every edge (no aggregation — neighbors decode the leaf).
pub fn gossip(model: &NetModel, bits_each: &[u64], degrees: &[usize]) -> RoundCost {
    let k = bits_each.len();
    if k <= 1 {
        return RoundCost::default();
    }
    debug_assert_eq!(degrees.len(), k);
    let mut secs: f64 = 0.0;
    let mut wire_bits = 0u64;
    let mut messages = 0u64;
    for (i, &b) in bits_each.iter().enumerate() {
        let deg = degrees[i];
        let t = model.latency_s
            + (deg * bits_to_bytes(b)) as f64 / model.bandwidth_bps;
        secs = secs.max(t);
        wire_bits += b * deg as u64;
        messages += deg as u64;
    }
    RoundCost { secs, wire_bits, messages }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> NetModel {
        NetModel::new(1e6, 0.0)
    }

    #[test]
    fn mesh_matches_seed_allgather_accounting() {
        let m = model();
        let bits = [800u64, 800, 800];
        let c = full_mesh(&m, &bits);
        assert_eq!(c.wire_bits, 800 * 2 * 3);
        assert_eq!(c.messages, 6);
        assert!((c.secs - 2.0 * 100.0 / 1e6).abs() < 1e-12);
        assert_eq!(full_mesh(&m, &[1234]), RoundCost::default());
    }

    #[test]
    fn mesh_secs_bit_identical_to_allgather_time() {
        // The allocation-free fold must reproduce NetModel::allgather_time
        // to the last bit (same float-op order), or `sim_net_time` would
        // drift off the reproducibility contract.
        let m = NetModel::new(117.0 * 1024.0 * 1024.0, 50e-6);
        let bits = [801u64, 17, 123_456, 0, 800];
        let bytes: Vec<usize> = bits.iter().map(|&b| bits_to_bytes(b)).collect();
        assert_eq!(
            full_mesh(&m, &bits).secs.to_bits(),
            m.allgather_time(&bytes).to_bits()
        );
    }

    #[test]
    fn ring_and_star_beat_mesh_at_k8_bandwidth_bound() {
        // Large equal payloads, zero latency: aggregation wins.
        let m = model();
        let bits = vec![8 * 100_000u64; 8];
        let mesh = full_mesh(&m, &bits);
        let ring_c = ring(&m, &bits);
        let star_c = star(&m, &bits);
        let hier_c = hierarchical(&m, &bits, 3);
        assert!(ring_c.secs < mesh.secs, "ring {} mesh {}", ring_c.secs, mesh.secs);
        assert!(star_c.secs < mesh.secs, "star {} mesh {}", star_c.secs, mesh.secs);
        assert!(hier_c.secs < mesh.secs, "hier {} mesh {}", hier_c.secs, mesh.secs);
        // and on total bytes too
        assert!(ring_c.wire_bits < mesh.wire_bits);
        assert!(star_c.wire_bits < mesh.wire_bits);
        assert!(hier_c.wire_bits < mesh.wire_bits);
    }

    #[test]
    fn ring_is_latency_bound_at_tiny_payloads() {
        // 2(K−1) α terms: at small b the mesh's single-phase latency wins —
        // the trade-off the topo_tradeoff bench surfaces.
        let m = NetModel::new(1e9, 50e-6);
        let bits = vec![8 * 64u64; 8];
        assert!(ring(&m, &bits).secs > full_mesh(&m, &bits).secs);
    }

    #[test]
    fn centralized_star_slower_than_mesh_for_equal_payloads() {
        // The seed's star test, verbatim semantics (absorbed from NetModel).
        let m = NetModel::new(1e6, 1e-4);
        let bytes = [1000usize; 4];
        let mesh_secs = full_mesh(&m, &[8000u64; 4]).secs;
        assert!(centralized_star_time(&m, &bytes) > mesh_secs * 0.99);
    }

    #[test]
    fn sharded_star_beats_centralized_star() {
        let m = model();
        let bits = vec![8 * 10_000u64; 8];
        let bytes = vec![10_000usize; 8];
        assert!(star(&m, &bits).secs < centralized_star_time(&m, &bytes));
    }

    #[test]
    fn hierarchical_handles_uneven_last_group() {
        let m = model();
        let bits = vec![800u64; 8]; // G=3 → groups of 3,3,2
        let c = hierarchical(&m, &bits, 3);
        // up: 5 member payloads; across: 3·2 aggregates; down: 5 aggregates
        assert_eq!(c.messages, 5 + 6 + 5);
        assert!(c.secs > 0.0 && c.wire_bits > 0);
        // one group degenerates to everything-in-one-group
        let c1 = hierarchical(&m, &bits, 1);
        assert_eq!(c1.messages, 7 + 0 + 7);
    }

    #[test]
    fn gossip_cost_scales_with_degree() {
        let m = model();
        let bits = vec![800u64; 6];
        let d2 = gossip(&m, &bits, &[2; 6]);
        let d4 = gossip(&m, &bits, &[4; 6]);
        assert!((d4.secs / d2.secs - 2.0).abs() < 1e-9);
        assert_eq!(d2.wire_bits, 800 * 2 * 6);
        assert_eq!(d4.messages, 24);
    }

    #[test]
    fn single_node_rounds_are_free() {
        let m = model();
        for c in [
            full_mesh(&m, &[64]),
            ring(&m, &[64]),
            star(&m, &[64]),
            hierarchical(&m, &[64], 1),
            gossip(&m, &[64], &[0]),
        ] {
            assert_eq!(c, RoundCost::default());
        }
    }
}
