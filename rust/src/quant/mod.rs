//! Unbiased random quantization of stochastic dual vectors — the `Q` half
//! of the paper's `CODE ∘ Q` pipeline, plus the QAda adaptive-level
//! machinery (§3.3), the layer-wise (Q-GenX-LW) partition/allocation
//! subsystem, and the Theorem 1 / Theorem 2 bound calculators.
//!
//! * [`levels`] — level sequences `ℓ = (0, ℓ_1, …, ℓ_s, 1)` (Definition 1):
//!   uniform (QSGD-style), exponential (NUQSGD-style), adaptive (QAda).
//! * [`quantizer`] — `Q_ℓ(v) = ‖v‖_q · s ⊙ [q_ℓ(u_1) … q_ℓ(u_d)]`, its
//!   deterministic core (explicit uniforms — bit-exact against the Pallas
//!   kernel), dequantization, and the bucketed variant torch_cgx uses.
//! * [`encode`] — the wire format: per-bucket `[norm f32][symbol codes +
//!   sign bits]` under a pluggable Ψ ([`crate::coding::SymbolCodec`]); see
//!   `docs/WIRE.md` for the full byte-layout reference.
//! * [`adaptive`] — sufficient statistics (weighted histogram of normalized
//!   coordinates; v2 payload and the per-layer v3 block), the (QAda)
//!   variance objective, coordinate-descent level optimization,
//!   Proposition 2 symbol probabilities.
//! * [`layers`] — [`LayerMap`]: named contiguous partition of the dual
//!   vector; [`LayerStats`]: per-layer sufficient statistics and the v3
//!   stat wire format that pools them across workers.
//! * [`alloc`] — greedy bit-budget allocator: redistributes a global
//!   bits/coordinate budget across layers by the Theorem-1 variance
//!   objective (configured via `[quant.layers] budget`, `docs/CONFIG.md`).
//! * [`bounds`] — Theorem 1 variance bound `ε_Q`, the QSGD/NUQSGD
//!   comparison bounds, Theorem 2 expected code length.
//! * [`contractive`] — the biased δ-contractive operator family (top-k,
//!   rand-k, rank-r) behind the `[quant.ef]` error-feedback pipeline:
//!   rank-stable top-k selection, seeded rand-k, subspace-iteration
//!   low-rank projection, and the sparse/low-rank wire frames
//!   (`docs/WIRE.md` §5).
//!
//! The per-worker state machine that drives all of this — including the
//! single-layer/FP32 paths and the layer-wise compressor — lives in
//! [`crate::coordinator::pipeline`].

pub mod adaptive;
pub mod alloc;
pub mod bounds;
pub mod contractive;
pub mod encode;
pub mod layers;
pub mod levels;
pub mod quantizer;

pub use adaptive::{optimize_levels, symbol_probs, SufficientStats};
pub use alloc::{allocate, Allocation, LayerProfile};
pub use bounds::{code_length_bound, epsilon_q, nuqsgd_variance_bound, qsgd_variance_bound};
pub use contractive::{auto_shape, ContractiveOp};
pub use encode::{
    decode_vector, decode_vector_into, encode_vector, encode_vector_into, WireCodec,
};
pub use layers::{LayerMap, LayerStats};
pub use levels::Levels;
pub use quantizer::{
    dequantize, dequantize_into, quantize, quantize_into, quantize_with_uniforms,
    QuantizedVector,
};
