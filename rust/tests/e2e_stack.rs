//! End-to-end stack tests over the AOT artifacts: HLO ⇄ Rust quantizer
//! parity, full GAN/LM driver smoke, CLI binary invocation.
//!
//! These tests skip (pass vacuously with a note) when `artifacts/` has not
//! been built; `make test` always builds artifacts first.

use qgenx::net::NetModel;
use qgenx::runtime::{default_artifacts_dir, Arg, Runtime};
use qgenx::train::{GanMode, GanTrainConfig, GanTrainer, LmTrainConfig, LmTrainer};

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir()?;
    Some(Runtime::open(dir).expect("artifacts present but unreadable"))
}

#[test]
fn pallas_quantize_artifact_agrees_with_rust_hot_path_statistically() {
    let Some(mut rt) = runtime() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let d = rt.manifest().quantize_d;
    let nl = rt.manifest().quantize_levels;
    let levels = qgenx::quant::Levels::uniform(nl - 2);
    let mut rng = qgenx::util::Rng::seed_from(99);

    // Over several random draws, HLO-vs-Rust disagreements must be rare
    // (f32 vs f64 boundary rounding only) and one-bin-sized.
    let mut total_mismatch = 0usize;
    for trial in 0..5 {
        let v = rng.gaussian_vec(d, 1.0 + trial as f64 * 0.3);
        let uniforms = rng.uniform_vec(d);
        let norm = [qgenx::util::norm2(&v) as f32];
        let hlo = rt
            .run(
                "quantize",
                &[
                    Arg::F32(&v, &[d]),
                    Arg::F32(&levels.full_f32(), &[nl]),
                    Arg::F32(&uniforms, &[d]),
                    Arg::F32(&norm, &[1]),
                ],
            )
            .unwrap()
            .remove(0);
        let qv = qgenx::quant::quantize_with_uniforms(&v, &levels, 2, 0, &uniforms).unwrap();
        let rust = qgenx::quant::dequantize(&qv, &levels);
        for i in 0..d {
            if (hlo[i] - rust[i]).abs() > 1e-6 * norm[0] {
                total_mismatch += 1;
            }
        }
    }
    assert!(
        total_mismatch <= 5 * d / 1000 + 10,
        "{total_mismatch} mismatches across 5 draws of d={d}"
    );
}

#[test]
fn gan_full_stack_all_modes() {
    let Some(mut rt) = runtime() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    for mode in [GanMode::Fp32, GanMode::Uq8, GanMode::Uq4] {
        let cfg = GanTrainConfig {
            mode,
            steps: 5,
            workers: 2,
            eval_every: 5,
            ..Default::default()
        };
        let mut tr = GanTrainer::new(&mut rt, cfg, NetModel::gbe()).unwrap();
        let rec = tr.train().unwrap();
        assert!(rec.get("metric").unwrap().last().unwrap().is_finite(), "{:?}", mode);
        assert!(tr.phases.gen_bp > 0.0 && tr.phases.disc_bp > 0.0 && tr.phases.pen_bp > 0.0);
    }
}

#[test]
fn lm_loss_drops_within_twenty_steps() {
    let Some(mut rt) = runtime() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let cfg = LmTrainConfig { steps: 20, workers: 2, eval_every: 5, ..Default::default() };
    let mut tr = LmTrainer::new(&mut rt, cfg, NetModel::gbe()).unwrap();
    let rec = tr.train().unwrap();
    let losses = rec.get("loss").unwrap();
    let first = losses.points.first().unwrap().1;
    let last = losses.last().unwrap();
    assert!(last < first, "loss should drop: {first} -> {last}");
    // Initial loss must be near ln(vocab) — sanity that the artifact and
    // the init blob match.
    let vocab = rt.manifest().lm.vocab as f64;
    assert!((first - vocab.ln()).abs() < 1.0, "init loss {first} vs ln V {}", vocab.ln());
}

#[test]
fn cli_binary_info_and_run() {
    // Drive the actual binary like a user would.
    let bin = env!("CARGO_BIN_EXE_qgenx");
    let out = std::process::Command::new(bin).arg("help").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));

    let out = std::process::Command::new(bin)
        .args(["run", "--iters", "60", "--workers", "2"])
        .env("TMPDIR", "/tmp")
        .current_dir("/tmp")
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("gap"), "no gap table in output: {stdout}");
    std::fs::remove_dir_all("/tmp/results").ok();

    let bad = std::process::Command::new(bin).arg("frobnicate").output().unwrap();
    assert!(!bad.status.success());
}
