//! Contractive (biased) compressors — the algorithmic core of the
//! error-feedback pipeline (`[quant.ef]`, `Compressor::Contractive`).
//!
//! Unlike the unbiased `CODE ∘ Q` stack (Definition 1 + Theorem 2), these
//! operators are *biased* but δ-contractive:
//!
//! ```text
//!   ‖x − C(x)‖² ≤ (1 − δ) ‖x‖²        for some δ ∈ (0, 1]
//! ```
//!
//! which is exactly the compressor class of the Three-Pillars analysis
//! (Beznosikov et al., 2023) and the unified local-GDA treatment (Zhang et
//! al., 2023) for VI / min-max problems. Bias is repaired by the per-worker
//! error-feedback recursion held in
//! [`crate::coordinator::pipeline`]:
//!
//! ```text
//!   a_t     = e_t + g_t                (accumulate)
//!   wire    = C(a_t)                   (compress, ship)
//!   e_{t+1} = a_t − Ĉ(a_t)             (remember what was dropped)
//! ```
//!
//! Three operators, each with its worst-case contraction factor exposed
//! via [`ContractiveOp::delta`]:
//!
//! * **top-k** — the k largest-magnitude coordinates, δ = k/d. Ties are
//!   broken by *ascending index* under a total order (see
//!   [`select_top_k`]), so replicated compressors on different ranks
//!   select identical supports — magnitude ties must never make gossip
//!   replicas diverge.
//! * **rand-k** — k distinct coordinates drawn from the compressor's own
//!   seeded PRNG, E[δ] = k/d. The chosen support travels on the wire, so
//!   decoding never replays the draw.
//! * **rank-r** — a subspace-iteration low-rank projection `U Uᵀ A` of the
//!   matrix-shaped dual (GAN / LM-proxy oracles), δ = r / min(rows, cols).
//!   Initialisation is a deterministic splitmix64 stream keyed on the
//!   shape — no PRNG state to checkpoint, identical on every replica.
//!
//! Wire frames (docs/WIRE.md §5):
//!
//! ```text
//!   sparse:   [u32 k][Elias-γ(gap_i + 1) …][k × f32 raw values]
//!   low-rank: [u32 r][rows·r × f32 U][cols·r × f32 V]
//! ```
//!
//! Sparse indices are delta-coded ascending (`gap_0 = idx_0`,
//! `gap_i = idx_i − idx_{i−1} − 1`); values are raw IEEE f32, so `k = d`
//! reproduces the uncompressed trajectory bit-for-bit. Both decoders use
//! the strict-tail convention: at most 7 padding bits, all zero.

use crate::coding::{elias, BitReader, BitWriter};
use crate::error::{Error, Result};
use crate::util::rng::{splitmix64, Rng};

/// One contractive operator, fully resolved (absolute `k` / shape).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ContractiveOp {
    /// Deterministic top-k by magnitude, index-ascending tie-break.
    TopK {
        /// Number of coordinates kept (1 ≤ k ≤ d).
        k: usize,
    },
    /// Seeded random-k with on-wire support.
    RandK {
        /// Number of coordinates kept (1 ≤ k ≤ d).
        k: usize,
    },
    /// Rank-r subspace-iteration projection of the `rows × cols` dual.
    RankR {
        /// Target rank (1 ≤ r ≤ min(rows, cols)).
        rank: usize,
        /// Matrix rows; `rows * cols` must equal the (layer) dimension.
        rows: usize,
        /// Matrix columns.
        cols: usize,
    },
}

impl ContractiveOp {
    /// Scheme name as it appears in config / telemetry.
    pub fn name(&self) -> &'static str {
        match self {
            ContractiveOp::TopK { .. } => "topk",
            ContractiveOp::RandK { .. } => "randk",
            ContractiveOp::RankR { .. } => "rankr",
        }
    }

    /// Worst-case contraction factor δ for a `d`-dimensional input:
    /// `k/d` for the sparsifiers, `r / min(rows, cols)` for rank-r
    /// (the top r of min(rows, cols) singular values carry at least an
    /// r/min share of the squared Frobenius norm).
    pub fn delta(&self, d: usize) -> f64 {
        match *self {
            ContractiveOp::TopK { k } | ContractiveOp::RandK { k } => {
                if d == 0 {
                    1.0
                } else {
                    k.min(d) as f64 / d as f64
                }
            }
            ContractiveOp::RankR { rank, rows, cols } => {
                let n = rows.min(cols).max(1);
                rank.min(n) as f64 / n as f64
            }
        }
    }

    /// Validate the operator against a concrete (layer) dimension `d`.
    pub fn validate(&self, d: usize) -> Result<()> {
        match *self {
            ContractiveOp::TopK { k } | ContractiveOp::RandK { k } => {
                if k == 0 || k > d {
                    return Err(Error::Quant(format!(
                        "{}: k = {k} out of range for dimension {d} (need 1 ≤ k ≤ d)",
                        self.name()
                    )));
                }
            }
            ContractiveOp::RankR { rank, rows, cols } => {
                if rows * cols != d {
                    return Err(Error::Quant(format!(
                        "rankr: shape {rows}×{cols} does not match dimension {d}"
                    )));
                }
                if rank == 0 || rank > rows.min(cols) {
                    return Err(Error::Quant(format!(
                        "rankr: rank = {rank} out of range for shape {rows}×{cols} \
                         (need 1 ≤ r ≤ min(rows, cols))"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Exact wire cost in bits of one frame produced by this operator on a
    /// `d`-dimensional input with the given selected support (sparse) —
    /// rank-r cost is shape-determined.
    pub fn frame_bits(&self, idx: &[u32]) -> u64 {
        match *self {
            ContractiveOp::TopK { .. } | ContractiveOp::RandK { .. } => {
                let mut bits = 32 + 32 * idx.len() as u64;
                let mut prev = 0u64;
                for (i, &ix) in idx.iter().enumerate() {
                    let gap = if i == 0 { ix as u64 } else { ix as u64 - prev - 1 };
                    bits += elias::gamma_len(gap + 1);
                    prev = ix as u64;
                }
                bits
            }
            ContractiveOp::RankR { rank, rows, cols } => {
                32 + 32 * ((rows + cols) * rank) as u64
            }
        }
    }
}

/// Deterministic near-square factorisation of `d`: the largest divisor
/// `rows ≤ √d` (so `rows ≤ cols` always). Used when `[quant.ef] rows = 0`.
pub fn auto_shape(d: usize) -> (usize, usize) {
    if d == 0 {
        return (1, 0);
    }
    let mut rows = (d as f64).sqrt().floor() as usize;
    while rows > 1 && d % rows != 0 {
        rows -= 1;
    }
    let rows = rows.max(1);
    (rows, d / rows)
}

/// Select the `k` largest-magnitude coordinates of `v` into `idx`
/// (ascending index order on return).
///
/// The selection is a *total order*: magnitude descending via
/// `f32::total_cmp`, then index ascending. Under magnitude ties the
/// lower index always wins, so the selected support is a pure function
/// of `v` — identical on every rank that holds a replica of the same
/// vector, independent of `select_nth_unstable_by` internals.
pub fn select_top_k(v: &[f32], k: usize, idx: &mut Vec<u32>) {
    idx.clear();
    idx.extend(0..v.len() as u32);
    let k = k.min(v.len());
    if k == 0 {
        idx.clear();
        return;
    }
    if k < v.len() {
        let by_rank = |&a: &u32, &b: &u32| {
            v[b as usize]
                .abs()
                .total_cmp(&v[a as usize].abs())
                .then(a.cmp(&b))
        };
        idx.select_nth_unstable_by(k - 1, by_rank);
        idx.truncate(k);
    }
    idx.sort_unstable();
}

/// Draw `k` distinct coordinates of a `d`-dimensional vector from `rng`
/// (partial Fisher–Yates over `perm`, a reusable scratch permutation).
/// `idx` holds the support in ascending order on return.
pub fn select_rand_k(d: usize, k: usize, rng: &mut Rng, perm: &mut Vec<u32>, idx: &mut Vec<u32>) {
    perm.clear();
    perm.extend(0..d as u32);
    let k = k.min(d);
    for i in 0..k {
        let j = i + rng.below((d - i) as u64) as usize;
        perm.swap(i, j);
    }
    idx.clear();
    idx.extend_from_slice(&perm[..k]);
    idx.sort_unstable();
}

/// Encode one sparse frame (WIRE.md §5) into `buf` (reused, cleared):
/// `[u32 k][γ(gap+1) …][f32 values]`, indices ascending. Returns the
/// exact payload length in bits (before byte padding).
pub fn encode_sparse_into(v: &[f32], idx: &[u32], buf: &mut Vec<u8>) -> u64 {
    buf.clear();
    let mut w = BitWriter::over(std::mem::take(buf));
    w.write_u32(idx.len() as u32);
    let mut prev = 0u64;
    for (i, &ix) in idx.iter().enumerate() {
        let gap = if i == 0 { ix as u64 } else { ix as u64 - prev - 1 };
        elias::gamma_encode(&mut w, gap + 1);
        prev = ix as u64;
    }
    for &ix in idx {
        w.write_f32(v[ix as usize]);
    }
    let bits = w.bit_len();
    *buf = w.finish();
    bits
}

/// Decode one sparse frame into `out` (zero-filled first, then the
/// carried values scattered onto their indices). `idx` is reusable
/// scratch that holds the decoded support on return. Returns `k`.
pub fn decode_sparse_into(bytes: &[u8], idx: &mut Vec<u32>, out: &mut [f32]) -> Result<usize> {
    out.fill(0.0);
    let mut r = BitReader::new(bytes);
    let k = r.read_u32()? as usize;
    if k > out.len() {
        return Err(Error::Codec(format!(
            "sparse frame: k = {k} exceeds dimension {}",
            out.len()
        )));
    }
    idx.clear();
    let mut prev = 0u64;
    for i in 0..k {
        let gap = elias::gamma_decode(&mut r)? - 1;
        let ix = if i == 0 { gap } else { prev + 1 + gap };
        if ix >= out.len() as u64 {
            return Err(Error::Codec(format!(
                "sparse frame: index {ix} out of bounds for dimension {}",
                out.len()
            )));
        }
        idx.push(ix as u32);
        prev = ix;
    }
    for &ix in idx.iter() {
        out[ix as usize] = r.read_f32()?;
    }
    strict_tail(r, bytes)?;
    Ok(k)
}

/// Rank-r subspace iteration: computes an orthonormal `U` (`rows × r`,
/// row-major) and `V = Aᵀ U` (`cols × r`, carrying the singular values)
/// such that `U Vᵀ = U Uᵀ A` is the projection of `A` onto the iterated
/// subspace. Initialisation is a splitmix64 stream keyed on the shape —
/// fully deterministic, no PRNG state consumed or stored.
pub fn low_rank_project(
    a: &[f32],
    rows: usize,
    cols: usize,
    rank: usize,
    u: &mut Vec<f32>,
    v: &mut Vec<f32>,
) {
    debug_assert_eq!(a.len(), rows * cols);
    let r = rank.min(rows).min(cols).max(1);
    v.clear();
    v.resize(cols * r, 0.0);
    let mut state = 0x9e37_79b9_7f4a_7c15u64
        ^ ((rows as u64) << 32)
        ^ ((cols as u64) << 16)
        ^ r as u64;
    for x in v.iter_mut() {
        // 24 high bits → uniform in [-1, 1): enough spread to seed the
        // subspace, exactly reproducible everywhere.
        *x = (splitmix64(&mut state) >> 40) as f32 / (1u64 << 23) as f32 - 1.0;
    }
    orthonormalize(v, cols, r);
    u.clear();
    u.resize(rows * r, 0.0);
    for _ in 0..2 {
        mat_ab(a, rows, cols, v, r, u);
        orthonormalize(u, rows, r);
        mat_atb(a, rows, cols, u, r, v);
        orthonormalize(v, cols, r);
    }
    mat_ab(a, rows, cols, v, r, u);
    orthonormalize(u, rows, r);
    mat_atb(a, rows, cols, u, r, v);
}

/// `out = U Vᵀ` — the shared reconstruction used by *both* the encoder's
/// error-memory update and the decoder, so sender and receiver agree on
/// `Ĉ(a)` bit-for-bit.
pub fn reconstruct_low_rank(
    u: &[f32],
    v: &[f32],
    rows: usize,
    cols: usize,
    rank: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * cols);
    for i in 0..rows {
        for j in 0..cols {
            let mut acc = 0.0f32;
            for l in 0..rank {
                acc += u[i * rank + l] * v[j * rank + l];
            }
            out[i * cols + j] = acc;
        }
    }
}

/// Encode one low-rank frame (WIRE.md §5): `[u32 r][U block][V block]`.
/// Returns the exact payload length in bits.
pub fn encode_low_rank_into(u: &[f32], v: &[f32], rank: usize, buf: &mut Vec<u8>) -> u64 {
    buf.clear();
    let mut w = BitWriter::over(std::mem::take(buf));
    w.write_u32(rank as u32);
    for &x in u {
        w.write_f32(x);
    }
    for &x in v {
        w.write_f32(x);
    }
    let bits = w.bit_len();
    *buf = w.finish();
    bits
}

/// Decode one low-rank frame into `out = U Vᵀ` (`rows × cols`). `u`/`v`
/// are reusable scratch holding the decoded factors on return.
pub fn decode_low_rank_into(
    bytes: &[u8],
    rows: usize,
    cols: usize,
    u: &mut Vec<f32>,
    v: &mut Vec<f32>,
    out: &mut [f32],
) -> Result<usize> {
    let mut r = BitReader::new(bytes);
    let rank = r.read_u32()? as usize;
    if rank == 0 || rank > rows.min(cols) {
        return Err(Error::Codec(format!(
            "low-rank frame: rank {rank} out of range for shape {rows}×{cols}"
        )));
    }
    u.clear();
    for _ in 0..rows * rank {
        u.push(r.read_f32()?);
    }
    v.clear();
    for _ in 0..cols * rank {
        v.push(r.read_f32()?);
    }
    strict_tail(r, bytes)?;
    reconstruct_low_rank(u, v, rows, cols, rank, out);
    Ok(rank)
}

/// Strict-tail check shared by both decoders: at most 7 residual bits,
/// all zero — truncated or oversized frames are wire errors, not noise.
fn strict_tail(mut r: BitReader, bytes: &[u8]) -> Result<()> {
    let consumed = r.bits_read();
    let total = bytes.len() as u64 * 8;
    if total < consumed || total - consumed >= 8 {
        return Err(Error::Codec(format!(
            "contractive frame: {} trailing bits after payload",
            total.saturating_sub(consumed)
        )));
    }
    let pad = (total - consumed) as u32;
    if pad > 0 && r.read_bits(pad)? != 0 {
        return Err(Error::Codec("contractive frame: nonzero padding".into()));
    }
    Ok(())
}

/// `u[·][l] = A v[·][l]` for each of the `r` columns (row-major blocks).
fn mat_ab(a: &[f32], rows: usize, cols: usize, v: &[f32], r: usize, u: &mut [f32]) {
    for i in 0..rows {
        for l in 0..r {
            let mut acc = 0.0f32;
            for j in 0..cols {
                acc += a[i * cols + j] * v[j * r + l];
            }
            u[i * r + l] = acc;
        }
    }
}

/// `v[·][l] = Aᵀ u[·][l]` for each of the `r` columns.
fn mat_atb(a: &[f32], rows: usize, cols: usize, u: &[f32], r: usize, v: &mut [f32]) {
    for j in 0..cols {
        for l in 0..r {
            let mut acc = 0.0f32;
            for i in 0..rows {
                acc += a[i * cols + j] * u[i * r + l];
            }
            v[j * r + l] = acc;
        }
    }
}

/// Modified Gram–Schmidt over the `r` columns of the `n × r` row-major
/// block `m`; near-zero columns are zeroed rather than normalised so the
/// projection degrades gracefully on (near-)zero inputs.
fn orthonormalize(m: &mut [f32], n: usize, r: usize) {
    for l in 0..r {
        for p in 0..l {
            let mut dot = 0.0f64;
            for i in 0..n {
                dot += m[i * r + l] as f64 * m[i * r + p] as f64;
            }
            let dot = dot as f32;
            for i in 0..n {
                m[i * r + l] -= dot * m[i * r + p];
            }
        }
        let mut nrm = 0.0f64;
        for i in 0..n {
            nrm += (m[i * r + l] as f64) * (m[i * r + l] as f64);
        }
        let nrm = nrm.sqrt();
        if nrm > 1e-12 {
            let inv = (1.0 / nrm) as f32;
            for i in 0..n {
                m[i * r + l] *= inv;
            }
        } else {
            for i in 0..n {
                m[i * r + l] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_sparse(v: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
        let mut idx = Vec::new();
        select_top_k(v, k, &mut idx);
        let mut buf = Vec::new();
        let bits = encode_sparse_into(v, &idx, &mut buf);
        assert_eq!(bits, ContractiveOp::TopK { k }.frame_bits(&idx));
        assert_eq!(buf.len() as u64, bits.div_ceil(8));
        let mut out = vec![f32::NAN; v.len()];
        let mut dec_idx = Vec::new();
        let got = decode_sparse_into(&buf, &mut dec_idx, &mut out).unwrap();
        assert_eq!(got, idx.len());
        assert_eq!(dec_idx, idx);
        (idx, out)
    }

    #[test]
    fn top_k_breaks_magnitude_ties_by_ascending_index() {
        // Four coordinates share |v| = 2.0; k = 2 must take the two
        // lowest indices among them, on every call, regardless of sign.
        let v = [2.0f32, -2.0, 0.5, 2.0, -2.0, 1.0];
        let mut idx = Vec::new();
        for _ in 0..8 {
            select_top_k(&v, 2, &mut idx);
            assert_eq!(idx, vec![0, 1]);
        }
        select_top_k(&v, 4, &mut idx);
        assert_eq!(idx, vec![0, 1, 3, 4]);
        // k = 5 pulls in the next-largest magnitude (index 5, |v| = 1).
        select_top_k(&v, 5, &mut idx);
        assert_eq!(idx, vec![0, 1, 3, 4, 5]);
    }

    #[test]
    fn top_k_is_identical_across_shuffled_replicas() {
        // Same vector on two "ranks" (independently allocated), heavy
        // ties: selections must agree element-for-element.
        let mut rng = Rng::seed_from(7);
        let mut v = vec![0.0f32; 257];
        for x in v.iter_mut() {
            // Quantized magnitudes → many exact ties.
            *x = ((rng.below(5) as f32) - 2.0) * 0.25;
        }
        let replica = v.clone();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for k in [1usize, 16, 128, 257] {
            select_top_k(&v, k, &mut a);
            select_top_k(&replica, k, &mut b);
            assert_eq!(a, b, "k = {k}");
        }
    }

    #[test]
    fn sparse_roundtrip_scatters_exact_values() {
        let mut rng = Rng::seed_from(11);
        let v = rng.gaussian_vec(64, 1.0);
        let (idx, out) = roundtrip_sparse(&v, 9);
        for i in 0..v.len() {
            if idx.contains(&(i as u32)) {
                assert_eq!(out[i], v[i], "selected values are raw f32");
            } else {
                assert_eq!(out[i], 0.0, "unselected coordinates decode to zero");
            }
        }
    }

    #[test]
    fn sparse_k_equals_d_is_the_identity() {
        let mut rng = Rng::seed_from(3);
        let v = rng.gaussian_vec(33, 2.0);
        let (_, out) = roundtrip_sparse(&v, 33);
        assert_eq!(out, v);
    }

    #[test]
    fn sparse_decoder_rejects_corrupt_frames() {
        let v = [1.0f32, -2.0, 3.0, 0.0];
        let mut idx = Vec::new();
        select_top_k(&v, 2, &mut idx);
        let mut buf = Vec::new();
        encode_sparse_into(&v, &idx, &mut buf);
        let mut out = vec![0.0f32; 4];
        let mut scratch = Vec::new();
        // Truncation.
        let t = &buf[..buf.len() - 1];
        assert!(decode_sparse_into(t, &mut scratch, &mut out).is_err());
        // Trailing garbage byte.
        let mut long = buf.clone();
        long.push(0xAB);
        assert!(decode_sparse_into(&long, &mut scratch, &mut out).is_err());
        // k beyond the dimension.
        let mut big = buf.clone();
        big[..4].copy_from_slice(&400u32.to_le_bytes());
        assert!(decode_sparse_into(&big, &mut scratch, &mut out).is_err());
    }

    #[test]
    fn rand_k_is_seed_deterministic_with_distinct_indices() {
        let (mut perm, mut idx) = (Vec::new(), Vec::new());
        let mut r1 = Rng::seed_from(42);
        select_rand_k(100, 17, &mut r1, &mut perm, &mut idx);
        let first = idx.clone();
        assert_eq!(first.len(), 17);
        for w in first.windows(2) {
            assert!(w[0] < w[1], "ascending and distinct");
        }
        let mut r2 = Rng::seed_from(42);
        select_rand_k(100, 17, &mut r2, &mut perm, &mut idx);
        assert_eq!(idx, first, "same seed → same support");
        let mut r3 = Rng::seed_from(43);
        select_rand_k(100, 17, &mut r3, &mut perm, &mut idx);
        assert_ne!(idx, first, "different seed → different support");
    }

    #[test]
    fn low_rank_recovers_an_exactly_rank_one_matrix() {
        let (rows, cols) = (6, 5);
        let mut a = vec![0.0f32; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                a[i * cols + j] = (i as f32 + 1.0) * (0.5 * j as f32 - 1.0);
            }
        }
        let (mut u, mut v) = (Vec::new(), Vec::new());
        low_rank_project(&a, rows, cols, 1, &mut u, &mut v);
        let mut out = vec![0.0f32; rows * cols];
        reconstruct_low_rank(&u, &v, rows, cols, 1, &mut out);
        for (x, y) in a.iter().zip(out.iter()) {
            assert!((x - y).abs() < 1e-4, "rank-1 input is reproduced: {x} vs {y}");
        }
    }

    #[test]
    fn low_rank_projection_is_contractive() {
        let mut rng = Rng::seed_from(19);
        let (rows, cols) = (8, 12);
        let a = rng.gaussian_vec(rows * cols, 1.0);
        for rank in [1usize, 2, 4, 8] {
            let (mut u, mut v) = (Vec::new(), Vec::new());
            low_rank_project(&a, rows, cols, rank, &mut u, &mut v);
            let mut c = vec![0.0f32; rows * cols];
            reconstruct_low_rank(&u, &v, rows, cols, rank, &mut c);
            let norm: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum();
            let resid: f64 = a.iter().zip(c.iter()).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
            assert!(
                resid <= norm * (1.0 + 1e-9),
                "rank {rank}: ‖a − C(a)‖² = {resid} must not exceed ‖a‖² = {norm}"
            );
        }
        // Full rank reproduces the matrix (up to subspace-iteration f32 noise).
        let (mut u, mut v) = (Vec::new(), Vec::new());
        low_rank_project(&a, rows, cols, rows.min(cols), &mut u, &mut v);
        let mut c = vec![0.0f32; rows * cols];
        reconstruct_low_rank(&u, &v, rows, cols, rows.min(cols), &mut c);
        let resid: f64 = a.iter().zip(c.iter()).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        assert!(resid < 1e-6, "full-rank residual {resid}");
    }

    #[test]
    fn low_rank_roundtrip_matches_sender_side_reconstruction() {
        let mut rng = Rng::seed_from(23);
        let (rows, cols, rank) = (6, 8, 2);
        let a = rng.gaussian_vec(rows * cols, 0.7);
        let (mut u, mut v) = (Vec::new(), Vec::new());
        low_rank_project(&a, rows, cols, rank, &mut u, &mut v);
        let mut sender = vec![0.0f32; rows * cols];
        reconstruct_low_rank(&u, &v, rows, cols, rank, &mut sender);
        let mut buf = Vec::new();
        let bits = encode_low_rank_into(&u, &v, rank, &mut buf);
        assert_eq!(bits, 32 + 32 * ((rows + cols) * rank) as u64);
        let (mut du, mut dv) = (Vec::new(), Vec::new());
        let mut receiver = vec![0.0f32; rows * cols];
        let got = decode_low_rank_into(&buf, rows, cols, &mut du, &mut dv, &mut receiver).unwrap();
        assert_eq!(got, rank);
        assert_eq!(receiver, sender, "both sides agree on Ĉ(a) bit-for-bit");
        // Corruption is rejected.
        let t = &buf[..buf.len() - 2];
        assert!(decode_low_rank_into(t, rows, cols, &mut du, &mut dv, &mut receiver).is_err());
    }

    #[test]
    fn delta_and_auto_shape_are_sane() {
        assert_eq!(ContractiveOp::TopK { k: 16 }.delta(64), 0.25);
        assert_eq!(ContractiveOp::RandK { k: 64 }.delta(64), 1.0);
        assert_eq!(
            ContractiveOp::RankR { rank: 4, rows: 32, cols: 40 }.delta(1280),
            4.0 / 32.0
        );
        assert_eq!(auto_shape(1024), (32, 32));
        assert_eq!(auto_shape(1280), (32, 40));
        assert_eq!(auto_shape(12), (3, 4));
        assert_eq!(auto_shape(13), (1, 13)); // prime → degenerate shape
        assert!(ContractiveOp::TopK { k: 0 }.validate(8).is_err());
        assert!(ContractiveOp::TopK { k: 9 }.validate(8).is_err());
        assert!(ContractiveOp::RankR { rank: 3, rows: 2, cols: 4 }.validate(8).is_err());
        assert!(ContractiveOp::RankR { rank: 2, rows: 2, cols: 4 }.validate(8).is_ok());
        assert!(ContractiveOp::RankR { rank: 2, rows: 3, cols: 4 }.validate(8).is_err());
    }
}
