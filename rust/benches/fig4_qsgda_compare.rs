//! E4 — Figure 4: Q-GenX vs QSGDA (Beznosikov et al. 2022, the only other
//! method without variance reduction). Same oracles, same compressors,
//! same network — only the update rule differs. On a stochastic monotone
//! problem, the extra-gradient template makes steady progress where plain
//! (quantized) gradient descent-ascent stalls or cycles.

use qgenx::benchkit::{scaled, Table};
use qgenx::config::ExperimentConfig;
use qgenx::coordinator::{run_experiment, run_qsgda_baseline};

fn main() {
    println!("== E4 / Figure 4: Q-GenX vs QSGDA ==\n");
    // Bilinear saddle is the regime where the extra-gradient template is
    // essential — plain GDA cycles on skew operators.
    let mut cfg = ExperimentConfig::default();
    cfg.problem.kind = "bilinear".into();
    cfg.problem.dim = 64;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.3;
    cfg.workers = 3;
    cfg.iters = scaled(4000, 500);
    cfg.eval_every = cfg.iters / 10;
    cfg.algo.gamma0 = 0.3;
    cfg.seed = 11;

    let rec_q = run_experiment(&cfg).unwrap();
    let rec_s = run_qsgda_baseline(&cfg).unwrap();

    let mut table = Table::new(&["iter", "Q-GenX dist", "QSGDA dist (avg)", "QSGDA dist (last)"]);
    let dq = rec_q.get("dist").unwrap();
    let ds = rec_s.get("dist").unwrap();
    let dsl = rec_s.get("dist_last").unwrap();
    let mut csv = Vec::new();
    for i in 0..dq.points.len() {
        let row = vec![
            format!("{:.0}", dq.points[i].0),
            format!("{:.5}", dq.points[i].1),
            format!("{:.5}", ds.points[i].1),
            format!("{:.5}", dsl.points[i].1),
        ];
        table.row(&row);
        csv.push(row);
    }
    table.print();

    let final_q = dq.last().unwrap();
    let final_s = ds.last().unwrap();
    println!("\nfinal distance-to-solution: Q-GenX {final_q:.5} vs QSGDA {final_s:.5}");
    println!("paper shape (Fig. 4): Q-GenX makes steady progress without variance reduction;");
    println!("QSGDA's decaying-step GDA cannot exploit the skew structure.");
    assert!(
        final_q < final_s,
        "Q-GenX should dominate QSGDA on the saddle: {final_q} vs {final_s}"
    );

    qgenx::benchkit::write_csv(
        "results/fig4_qsgda.csv",
        &["iter", "qgenx", "qsgda_avg", "qsgda_last"],
        &csv,
    )
    .unwrap();
    println!("csv -> results/fig4_qsgda.csv");
}
