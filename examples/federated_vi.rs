//! Federated-learning scenario (paper §1: "multiple clients, e.g. a few
//! hospitals... learn a model collaboratively without sharing local
//! data"): K clients solve a shared co-coercive VI with *relative-noise*
//! oracles over the **threaded** coordinator — real worker threads, real
//! encoded bytes through the allgather transport, replicated state.
//!
//! Shows the Theorem-4 regime: under relative noise the adaptive step-size
//! stays bounded away from zero and the gap falls at the fast rate, while
//! the same code under absolute noise falls at the O(1/sqrt(T)) rate.
//!
//! ```bash
//! cargo run --release --example federated_vi
//! ```

use qgenx::config::{ExperimentConfig, Variant};
use qgenx::coordinator::run_threaded;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "federated".into();
    cfg.problem.kind = "cocoercive".into();
    cfg.problem.dim = 256;
    cfg.workers = 6; // six hospitals
    cfg.iters = 1500;
    cfg.eval_every = 150;
    cfg.algo.variant = Variant::OptimisticDualAveraging; // 1 oracle call/iter
    cfg.net.latency_s = 20e-3; // WAN latency between hospitals
    cfg.net.bandwidth_bps = 12.5e6; // 100 Mbit/s uplinks

    for noise in ["relative", "absolute"] {
        cfg.problem.noise = noise.into();
        cfg.problem.rel_c = 1.0;
        cfg.problem.sigma = 0.5;
        println!("== {noise} noise, K={} clients, OptDA variant, threaded ==", cfg.workers);
        let run = run_threaded(&cfg)?;
        let rec = &run.recorder;
        println!("  iter        gap       gamma    sim-time(s)");
        let gaps = rec.get("gap").unwrap();
        let gammas = rec.get("gamma").unwrap();
        let times = rec.get("sim_time_cum").unwrap();
        for i in 0..gaps.points.len() {
            println!(
                "  {:>6.0}  {:>10.5}  {:>9.4}  {:>10.2}",
                gaps.points[i].0, gaps.points[i].1, gammas.points[i].1, times.points[i].1
            );
        }
        println!(
            "  replicas in sync: {} | total bits {:.2e} | level updates {}\n",
            run.replicas.windows(2).all(|w| w[0] == w[1]),
            rec.scalar("total_bits").unwrap(),
            rec.scalar("level_updates").unwrap(),
        );
    }
    println!("note: under relative noise gamma stabilizes (fast O(1/T) regime, Thm 4);");
    println!("under absolute noise gamma decays ~1/sqrt(t) (order-optimal regime, Thm 3).");
    Ok(())
}
