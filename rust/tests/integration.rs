//! Cross-module integration tests: config file → coordinator → metrics;
//! threaded vs inline equivalence; wire-format interop under level drift;
//! failure injection on the transport payloads.

use qgenx::config::{ExperimentConfig, LevelScheme, QuantMode, Variant};
use qgenx::coordinator::{run_experiment, run_qsgda_baseline, run_threaded, Compressor};
use qgenx::net::NetModel;
use qgenx::util::Rng;

fn smoke_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 3;
    cfg.iters = 200;
    cfg.eval_every = 50;
    cfg.problem.dim = 16;
    cfg.problem.kind = "quadratic".into();
    cfg.problem.sigma = 0.3;
    cfg.quant.update_every = 60;
    cfg
}

#[test]
fn config_file_to_run_to_csv() {
    let toml = r#"
name = "itest"
workers = 2
iters = 150
eval_every = 50
out_dir = "/tmp/qgenx_itest"

[problem]
kind = "bilinear"
dim = 32
sigma = 0.2

[quant]
mode = "uq4"
scheme = "adaptive"
codec = "huffman"

[algo]
variant = "de"
gamma0 = 0.5
"#;
    let path = "/tmp/qgenx_itest_cfg.toml";
    std::fs::write(path, toml).unwrap();
    let cfg = ExperimentConfig::load(path).unwrap();
    assert_eq!(cfg.name, "itest");
    assert_eq!(cfg.problem.dim, 32);
    let rec = run_experiment(&cfg).unwrap();
    assert!(rec.get("gap").is_some());
    let csv = format!("{}/itest.csv", cfg.out_dir);
    rec.to_csv(&csv).unwrap();
    let contents = std::fs::read_to_string(&csv).unwrap();
    assert!(contents.lines().count() > 5);
    std::fs::remove_file(path).ok();
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn every_problem_kind_runs_through_the_full_pipeline() {
    for kind in ["bilinear", "quadratic", "cocoercive", "rotation", "game"] {
        let mut cfg = smoke_cfg();
        cfg.problem.kind = kind.into();
        cfg.iters = 60;
        let rec = run_experiment(&cfg)
            .unwrap_or_else(|e| panic!("{kind} failed: {e}"));
        let r = rec.get("residual").unwrap().last().unwrap();
        assert!(r.is_finite(), "{kind}: residual {r}");
    }
}

#[test]
fn every_noise_model_runs() {
    for noise in ["none", "absolute", "relative", "rcd", "player"] {
        let mut cfg = smoke_cfg();
        cfg.problem.noise = noise.into();
        cfg.iters = 60;
        let rec = run_experiment(&cfg).unwrap_or_else(|e| panic!("{noise} failed: {e}"));
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
    }
}

#[test]
fn every_codec_and_scheme_combination_runs() {
    for codec in ["fixed", "elias-gamma", "elias-delta", "huffman"] {
        for scheme in [LevelScheme::Uniform, LevelScheme::Exponential, LevelScheme::Adaptive] {
            let mut cfg = smoke_cfg();
            cfg.iters = 40;
            cfg.quant.codec = qgenx::coding::SymbolCodec::parse(codec).unwrap();
            cfg.quant.scheme = scheme;
            cfg.quant.update_every = 15;
            let rec = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{codec}/{} failed: {e}", scheme.name()));
            assert!(rec.scalar("total_bits").unwrap() > 0.0);
        }
    }
}

#[test]
fn threaded_and_inline_agree_on_round_counts_and_convergence() {
    let cfg = smoke_cfg();
    let inline = run_experiment(&cfg).unwrap();
    let threaded = run_threaded(&cfg).unwrap();
    assert_eq!(
        inline.scalar("rounds").unwrap(),
        threaded.recorder.scalar("rounds").unwrap()
    );
    // Both converge to a similar gap band (RNG streams interleave
    // differently, so compare loosely).
    let gi = inline.get("gap").unwrap().last().unwrap();
    let gt = threaded.recorder.get("gap").unwrap().last().unwrap();
    assert!(gi < 1.0 && gt < 1.0, "inline {gi} threaded {gt}");
}

#[test]
fn qsgda_baseline_uses_same_bit_budget_per_round() {
    let mut cfg = smoke_cfg();
    cfg.quant.scheme = LevelScheme::Uniform;
    cfg.quant.codec = qgenx::coding::SymbolCodec::Fixed;
    cfg.algo.variant = Variant::DualAveraging; // one exchange/iter like QSGDA
    let q = run_experiment(&cfg).unwrap();
    let s = run_qsgda_baseline(&cfg).unwrap();
    let bq = q.scalar("total_bits").unwrap();
    let bs = s.scalar("total_bits").unwrap();
    assert!((bq - bs).abs() / bq < 0.02, "bit budgets should match: {bq} vs {bs}");
}

#[test]
fn compressors_interoperate_after_synchronized_level_updates() {
    // Two compressors drift through 3 level updates; cross-decoding must
    // stay exact (the distributed wire contract under schedule U).
    let mut cfg = qgenx::config::QuantConfig::default();
    cfg.update_every = 10;
    let mut a = Compressor::from_config(&cfg, Rng::seed_from(1)).unwrap();
    let mut b = Compressor::from_config(&cfg, Rng::seed_from(2)).unwrap();
    let mut rng = Rng::seed_from(3);
    for round in 0..30 {
        let va = rng.gaussian_vec(2048, 1.0);
        let vb = rng.gaussian_vec(2048, 1.0);
        let (wa, _) = a.compress(&va).unwrap();
        let (wb, _) = b.compress(&vb).unwrap();
        // cross-decode: b decodes a's bytes, a decodes b's
        let mut out_ab = vec![0.0f32; 2048];
        let mut out_ba = vec![0.0f32; 2048];
        b.decompress(&wa, &mut out_ab).unwrap();
        a.decompress(&wb, &mut out_ba).unwrap();
        // self-decode must equal peer-decode
        let mut out_aa = vec![0.0f32; 2048];
        a.decompress(&wa, &mut out_aa).unwrap();
        assert_eq!(out_aa, out_ab, "round {round}: decode divergence");
        if round % 10 == 9 {
            let sa = a.stats_payload();
            let sb = b.stats_payload();
            a.update_levels(&[&sa, &sb]).unwrap();
            b.update_levels(&[&sa, &sb]).unwrap();
            assert_eq!(a.levels().unwrap(), b.levels().unwrap());
        }
    }
    assert_eq!(a.updates(), 3);
}

#[test]
fn corrupted_wire_bytes_are_rejected_not_misdecoded() {
    let cfg = qgenx::config::QuantConfig::default();
    let mut c = Compressor::from_config(&cfg, Rng::seed_from(4)).unwrap();
    let mut rng = Rng::seed_from(5);
    let v = rng.gaussian_vec(1024, 1.0);
    let (wire, _) = c.compress(&v).unwrap();
    let mut out = vec![0.0f32; 1024];
    // Truncation must error.
    assert!(c.decompress(&wire[..wire.len() / 3], &mut out).is_err());
    // Bit flips in the norm field: either an error or a finite decode —
    // never a panic.
    let mut corrupted = wire.clone();
    corrupted[0] ^= 0xFF;
    corrupted[1] ^= 0xAA;
    match c.decompress(&corrupted, &mut out) {
        Ok(()) => assert!(out.iter().all(|x| x.is_finite() || x.is_nan() || x.is_infinite())),
        Err(_) => {}
    }
}

#[test]
fn fp32_mode_is_bit_exact_through_the_coordinator() {
    let mut cfg = smoke_cfg();
    cfg.quant.mode = QuantMode::Fp32;
    cfg.problem.noise = "none".into();
    cfg.iters = 400;
    cfg.algo.gamma0 = 0.3;
    // Without quantization and without noise, two runs are identical and
    // converge deterministically.
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.get("dist").unwrap().ys(), b.get("dist").unwrap().ys());
    let dist = a.get("dist").unwrap();
    let first = dist.points.first().unwrap().1;
    let last = dist.last().unwrap();
    assert!(last < 0.5 * first, "deterministic run should contract: {first} -> {last}");
}

#[test]
fn simulated_time_scales_with_bandwidth() {
    // zero latency + a big payload so bandwidth dominates the model.
    // rotation: O(d) apply and O(1) construction (quadratic would build an
    // O(d^2) matrix with O(d^3) work — not viable at d=4096 in debug).
    let mut slow = smoke_cfg();
    slow.problem.kind = "rotation".into();
    slow.problem.dim = 4096;
    slow.iters = 50;
    slow.eval_every = 50;
    slow.net.latency_s = 0.0;
    slow.net.bandwidth_bps = 1e6;
    let mut fast = slow.clone();
    fast.net.bandwidth_bps = 1e9;
    let t_slow = run_experiment(&slow).unwrap().scalar("sim_net_time").unwrap();
    let t_fast = run_experiment(&fast).unwrap().scalar("sim_net_time").unwrap();
    assert!(
        t_slow > 50.0 * t_fast,
        "1000x bandwidth should give ~1000x net time: {t_slow} vs {t_fast}"
    );
}

#[test]
fn k_workers_send_k_times_the_bits() {
    let mut c2 = smoke_cfg();
    c2.workers = 2;
    c2.quant.scheme = LevelScheme::Uniform;
    c2.quant.codec = qgenx::coding::SymbolCodec::Fixed;
    let mut c4 = c2.clone();
    c4.workers = 4;
    let b2 = run_experiment(&c2).unwrap().scalar("total_bits").unwrap();
    let b4 = run_experiment(&c4).unwrap().scalar("total_bits").unwrap();
    // all-to-all: bits scale as K(K-1) -> 4*3 / (2*1) = 6x
    let ratio = b4 / b2;
    assert!((ratio - 6.0).abs() < 0.2, "K-scaling of traffic: {ratio} (expect 6)");
}

#[test]
fn net_model_matches_manual_alpha_beta() {
    let net = NetModel::new(1e8, 1e-4);
    let t = net.allgather_time(&[1_000_000, 1_000_000, 1_000_000]);
    // each sends 2 copies of 1MB at 100MB/s = 0.02s + latency
    assert!((t - (1e-4 + 0.02)).abs() < 1e-9);
}
