//! `qgenx` — leader entrypoint / CLI for the Q-GenX reproduction.
//!
//! Subcommands:
//!
//! * `run [--config <file.toml>] [--threaded]` — one VI experiment through
//!   the coordinator (Algorithm 1); prints the gap trajectory and traffic
//!   summary, writes CSV to the configured `out_dir`.
//! * `gan [--mode fp32|uq8|uq4] [--steps N] [--workers K]` — the paper's
//!   WGAN-GP experiment on the AOT artifacts.
//! * `lm [--steps N] [--workers K] [--optimizer msgd|qgenx] [--mode ...]`
//!   — distributed quantized LM training (the E2E driver).
//! * `worker --rank R --connect ADDR [run flags]` — one rank of a
//!   socket-transport group in this process (rank 0 hosts the rendezvous
//!   and prints the run summary; see `docs/WIRE.md`).
//! * `launch [--addr ADDR] [run flags]` — spawn `K` local `worker`
//!   subprocesses over a Unix-domain (default) or TCP socket and wait.
//! * `info` — print the artifact manifest summary.
//!
//! The argument parser is hand-rolled (`--key value` / `--flag`); no clap
//! in the offline build image.

use qgenx::config::{ExperimentConfig, QuantMode};
use qgenx::coordinator::{run_threaded, Control, Observer, Session, StepReport, StopAtGap};
use qgenx::metrics::Recorder;
use qgenx::net::{
    FaultPlan, FaultyTransport, NetModel, SocketHub, SocketOpts, SocketTransport, Transport,
};
use qgenx::runtime::{default_artifacts_dir, Runtime};
use qgenx::train::{GanMode, GanTrainConfig, GanTrainer, LmOptimizer, LmTrainConfig, LmTrainer};
use std::collections::HashMap;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        print_help();
        return ExitCode::SUCCESS;
    };
    let flags = match parse_flags(rest) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(&flags),
        "gan" => cmd_gan(&flags),
        "lm" => cmd_lm(&flags),
        "worker" => cmd_worker(&flags),
        "launch" => cmd_launch(&flags),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`");
            print_help();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "qgenx — Distributed Extra-gradient with Optimal Complexity and Communication Guarantees\n\
         \n\
         USAGE: qgenx <command> [--key value ...]\n\
         \n\
         COMMANDS:\n\
           run    VI experiment via the coordinator   [--config f.toml] [--threaded] [--qsgda] [--algo qgenx|peg|eg-aa] [--topo full-mesh|star|ring|hierarchical|gossip] [--rewire-every N] [--local H] [--staleness S] [--straggler-rate p] [--layers N|name:end,...,last] [--ef off|topk:k|randk:k|rankr:r[:rows]] [--watch] [--stop-at-gap g] [--telemetry mem|path.jsonl]\n\
           gan    WGAN-GP experiment (paper §5)       [--mode fp32|uq8|uq4] [--steps N] [--workers K] [--layerwise]\n\
           lm     distributed quantized LM training   [--steps N] [--workers K] [--optimizer msgd|qgenx] [--algo qgenx|peg|eg-aa] [--layers N] [--ef off|topk:k|randk:k|rankr:r[:rows]]\n\
           worker one socket-transport rank           --rank R --connect HOST:PORT|unix:PATH [--timeout-ms N] [--fault kind@rank:round[:arg],...] [run flags; rank 0 hosts the rendezvous and reports]\n\
           launch spawn K local socket workers        [--addr HOST:PORT|unix:PATH] [run flags incl. --fault, forwarded to every worker]\n\
           info   print the artifact manifest summary\n\
           help   this message"
    );
}

/// `--watch`: stream every eval step's report as the run progresses.
struct WatchProgress;

impl Observer for WatchProgress {
    fn on_step(&mut self, r: &StepReport) -> Control {
        if r.evaluated {
            let gap = r.gap.map(|g| format!("{g:.6e}")).unwrap_or_else(|| "-".into());
            let cons = r.consensus.map(|c| format!("  consensus={c:.5}")).unwrap_or_default();
            println!(
                "  [watch] t={:>6}/{} gap={gap} gamma={:.5} bits={}{cons}",
                r.t, r.iters, r.gamma, r.bits_cum
            );
        }
        Control::Continue
    }
}

type Flags = HashMap<String, String>;

fn parse_flags(args: &[String]) -> Result<Flags, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let key = a
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --flag, got `{a}`"))?;
        if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            map.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            map.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(map)
}

fn flag_usize(flags: &Flags, key: &str, default: usize) -> usize {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Build the VI experiment config shared by `run`, `worker` and `launch`:
/// `--config` file, then the common flag overrides on top.
fn run_cfg_from_flags(flags: &Flags) -> Result<ExperimentConfig, String> {
    let mut cfg = match flags.get("config") {
        Some(path) => ExperimentConfig::load(path).map_err(|e| e.to_string())?,
        None => ExperimentConfig::default(),
    };
    if let Some(w) = flags.get("workers") {
        cfg.workers = w.parse().map_err(|_| "bad --workers")?;
    }
    if let Some(t) = flags.get("iters") {
        cfg.iters = t.parse().map_err(|_| "bad --iters")?;
    }
    if let Some(m) = flags.get("mode") {
        cfg.quant.mode = QuantMode::parse(m).map_err(|e| e.to_string())?;
    }
    if let Some(t) = flags.get("topo") {
        cfg.topo.kind = t.clone();
    }
    if let Some(m) = flags.get("algo") {
        cfg.algo.method = qgenx::config::Method::parse(m).map_err(|e| e.to_string())?;
    }
    if let Some(h) = flags.get("local") {
        cfg.local.steps = h.parse().map_err(|_| "bad --local")?;
    }
    if let Some(t) = flags.get("timeout-ms") {
        cfg.net.timeout_ms = t.parse().map_err(|_| "bad --timeout-ms")?;
    }
    if let Some(r) = flags.get("rewire-every") {
        cfg.topo.rewire_every = r.parse().map_err(|_| "bad --rewire-every")?;
    }
    if let Some(s) = flags.get("staleness") {
        cfg.local.staleness = s.parse().map_err(|_| "bad --staleness")?;
    }
    if let Some(r) = flags.get("straggler-rate") {
        cfg.local.straggler_rate = r.parse().map_err(|_| "bad --straggler-rate")?;
    }
    if let Some(spec) = flags.get("layers") {
        // Replace the partition (names + bounds) but keep a config file's
        // budget — the flag is the quick way to try a different split.
        let parsed =
            qgenx::config::LayersConfig::parse_cli(spec).map_err(|e| e.to_string())?;
        cfg.quant.layers.names = parsed.names;
        cfg.quant.layers.bounds = parsed.bounds;
        cfg.quant.layers.overrides.clear();
    }
    if let Some(spec) = flags.get("ef") {
        // `off` | `topk:<k>` | `randk:<k>` | `rankr:<rank>[:<rows>]` —
        // replaces a config file's [quant.ef] table (docs/CONFIG.md).
        cfg.quant.ef = qgenx::config::EfConfig::parse_cli(spec).map_err(|e| e.to_string())?;
    }
    Ok(cfg)
}

/// The one-line run header every coordinator entrypoint prints.
fn print_run_header(kind: &str, cfg: &ExperimentConfig) {
    println!(
        "{kind}: problem={} dim={} K={} T={} mode={} algo={} variant={} topo={} local_steps={} layers={}",
        cfg.problem.kind,
        cfg.problem.dim,
        cfg.workers,
        cfg.iters,
        cfg.quant.mode.name(),
        cfg.algo.method.name(),
        cfg.algo.variant.name(),
        cfg.topo.kind,
        cfg.local.steps,
        if cfg.quant.layers.names.is_empty() {
            "none".to_string()
        } else {
            cfg.quant.layers.names.join(",")
        }
    );
}

/// Gap trajectory + summary scalars + CSV, shared by `run` and `worker`
/// (rank 0): identical output lets the CI transport-smoke job diff the
/// two execution modes textually.
fn print_run_summary(cfg: &ExperimentConfig, rec: &Recorder) -> Result<(), String> {
    if let Some(gaps) = rec.get("gap") {
        println!("  iter        gap");
        for (x, y) in &gaps.points {
            println!("  {x:>6.0}  {y:>12.6e}");
        }
    }
    for key in [
        "total_bits",
        "bits_per_round_per_worker",
        "sim_net_time",
        "level_updates",
        "consensus_dist",
        "max_link_bytes",
    ] {
        if let Some(v) = rec.scalar(key) {
            println!("  {key} = {v:.3}");
        }
    }
    for (key, v) in &rec.scalars {
        if key.starts_with("layer_") {
            println!("  {key} = {v:.3}");
        }
    }
    let out = format!("{}/{}.csv", cfg.out_dir, cfg.name);
    rec.to_csv(&out).map_err(|e| e.to_string())?;
    println!("  csv -> {out}");
    Ok(())
}

fn cmd_run(flags: &Flags) -> Result<(), String> {
    let cfg = run_cfg_from_flags(flags)?;
    if flags.contains_key("qsgda") && cfg.local.steps > 1 {
        return Err("--qsgda has no local-steps path; drop --local".into());
    }
    if flags.contains_key("qsgda") && cfg.algo.method != qgenx::config::Method::QGenX {
        return Err("--qsgda is its own baseline update rule; drop --algo".into());
    }
    if (flags.contains_key("watch")
        || flags.contains_key("stop-at-gap")
        || flags.contains_key("telemetry"))
        && (flags.contains_key("qsgda") || flags.contains_key("threaded"))
    {
        return Err(
            "--watch/--stop-at-gap/--telemetry drive an inline Session; drop --qsgda/--threaded \
             (threaded runs honour the QGENX_TELEMETRY env knob instead)"
                .into(),
        );
    }
    print_run_header("run", &cfg);
    let rec = if flags.contains_key("qsgda") {
        qgenx::coordinator::run_qsgda_baseline(&cfg).map_err(|e| e.to_string())?
    } else if flags.contains_key("threaded") {
        run_threaded(&cfg).map_err(|e| e.to_string())?.recorder
    } else {
        // The steppable Session is the run API; wire up the CLI's streaming
        // and early-stop hooks as observers (docs/API.md).
        let mut builder = Session::builder(cfg.clone());
        if flags.contains_key("watch") {
            builder = builder.observer(Box::new(WatchProgress));
        }
        if let Some(g) = flags.get("stop-at-gap") {
            let g: f64 = g.parse().map_err(|_| "bad --stop-at-gap")?;
            builder = builder.observer(Box::new(StopAtGap(g)));
        }
        if let Some(v) = flags.get("telemetry") {
            // Same grammar as QGENX_TELEMETRY: `mem`/`1` for the in-memory
            // ring, anything else is a JSONL sink path (docs/OBSERVABILITY.md).
            // A bare `--telemetry` parses as "true" — treat it as `mem`.
            let v = if v == "true" { "mem" } else { v.as_str() };
            let tcfg = qgenx::telemetry::TelemetryConfig::parse(v)
                .ok_or("bad --telemetry: use `mem` or a JSONL path")?;
            builder = builder.telemetry(tcfg);
        }
        builder.build().map_err(|e| e.to_string())?.run().map_err(|e| e.to_string())?
    };
    print_run_summary(&cfg, &rec)
}

/// One rank of a socket-transport group: rank 0 binds the rendezvous at
/// `--connect` and accepts its peers; every other rank dials in. All ranks
/// then drive the same [`Session`] the in-process coordinators use — only
/// rank 0 prints the summary and writes the CSV (and, with `--telemetry`,
/// owns the JSONL sink).
fn cmd_worker(flags: &Flags) -> Result<(), String> {
    let cfg = run_cfg_from_flags(flags)?;
    let rank: usize = flags
        .get("rank")
        .ok_or("worker needs --rank")?
        .parse()
        .map_err(|_| "bad --rank")?;
    let addr = flags.get("connect").ok_or("worker needs --connect (HOST:PORT or unix:PATH)")?;
    if rank >= cfg.workers {
        return Err(format!("--rank {rank} out of range for K = {}", cfg.workers));
    }
    let opts = SocketOpts::from_config(&cfg.net);
    let mut transport: std::sync::Arc<dyn Transport> = if rank == 0 {
        let hub = SocketHub::bind(addr, cfg.workers, opts).map_err(|e| e.to_string())?;
        hub.accept().map_err(|e| e.to_string())?
    } else {
        SocketTransport::connect(addr, rank, cfg.workers, opts).map_err(|e| e.to_string())?
    };
    // `--fault` wraps this rank's endpoint in the deterministic chaos
    // decorator (docs/SCENARIOS.md); the schedule names the ranks it hits,
    // so the same spec is safely forwarded to every worker by `launch`.
    if let Some(spec) = flags.get("fault") {
        let plan = FaultPlan::parse(spec).map_err(|e| e.to_string())?;
        transport = FaultyTransport::wrap(transport, plan);
    }
    let mut builder = Session::builder(cfg.clone()).transport(transport, rank);
    if let Some(v) = flags.get("telemetry") {
        let v = if v == "true" { "mem" } else { v.as_str() };
        let tcfg = qgenx::telemetry::TelemetryConfig::parse(v)
            .ok_or("bad --telemetry: use `mem` or a JSONL path")?;
        builder = builder.telemetry(tcfg);
    }
    if rank == 0 {
        print_run_header("worker", &cfg);
    }
    let mut session = builder.build().map_err(|e| e.to_string())?;
    session.run_to(cfg.iters).map_err(|e| e.to_string())?;
    let rec = session.into_recorder();
    if rank == 0 {
        print_run_summary(&cfg, &rec)?;
    }
    Ok(())
}

/// Spawn `K` `worker` subprocesses of this binary against one rendezvous
/// address and wait for all of them; the first failure kills the rest of
/// the group (their rounds have already poisoned — the kill only reaps).
fn cmd_launch(flags: &Flags) -> Result<(), String> {
    let cfg = run_cfg_from_flags(flags)?;
    let addr = match flags.get("addr") {
        Some(a) => a.clone(),
        #[cfg(unix)]
        None => format!(
            "unix:{}/qgenx-{}.sock",
            std::env::temp_dir().display(),
            std::process::id()
        ),
        #[cfg(not(unix))]
        None => return Err("launch needs --addr HOST:PORT on this platform".into()),
    };
    let exe = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    // Forward every run flag to every worker; `--addr` is launch-local and
    // `--rank`/`--connect` are per-worker (set below, not forwardable).
    let mut forwarded: Vec<String> = Vec::new();
    let mut keys: Vec<&String> = flags
        .keys()
        .filter(|k| !matches!(k.as_str(), "addr" | "rank" | "connect"))
        .collect();
    keys.sort();
    for key in keys {
        forwarded.push(format!("--{key}"));
        let v = &flags[key];
        if v != "true" {
            forwarded.push(v.clone());
        }
    }
    println!("launch: K={} addr={addr}", cfg.workers);
    let mut children: Vec<(usize, std::process::Child)> = Vec::with_capacity(cfg.workers);
    for rank in 0..cfg.workers {
        // Rank 0 first: it binds the rendezvous; later ranks dial with
        // retry until the handshake deadline, so start order beyond that
        // doesn't matter.
        let child = std::process::Command::new(&exe)
            .arg("worker")
            .args(["--rank", &rank.to_string(), "--connect", &addr])
            .args(&forwarded)
            .spawn()
            .map_err(|e| format!("spawn worker {rank}: {e}"));
        match child {
            Ok(c) => children.push((rank, c)),
            Err(e) => {
                for (_, c) in children.iter_mut() {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                return Err(e);
            }
        }
    }
    let mut failure: Option<String> = None;
    for i in 0..children.len() {
        let (rank, child) = &mut children[i];
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => failure = Some(format!("worker {rank} exited with {status}")),
            Err(e) => failure = Some(format!("wait on worker {rank}: {e}")),
        }
        if failure.is_some() {
            break;
        }
    }
    if failure.is_some() {
        // Peers of a dead worker error out of their next round (poison
        // semantics), so these kills are belt-and-braces against a worker
        // wedged before its first exchange.
        for (_, c) in children.iter_mut() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
    match failure {
        Some(msg) => Err(msg),
        None => Ok(()),
    }
}

fn open_runtime() -> Result<Runtime, String> {
    let dir = default_artifacts_dir()
        .ok_or("artifacts not found — run `make artifacts` first (or set QGENX_ARTIFACTS)")?;
    Runtime::open(dir).map_err(|e| e.to_string())
}

fn cmd_gan(flags: &Flags) -> Result<(), String> {
    let mut rt = open_runtime()?;
    let mode = flags
        .get("mode")
        .map(|m| GanMode::parse(m).ok_or(format!("bad --mode {m}")))
        .transpose()?
        .unwrap_or(GanMode::Uq4);
    let cfg = GanTrainConfig {
        mode,
        steps: flag_usize(flags, "steps", 200),
        workers: flag_usize(flags, "workers", 3),
        eval_every: flag_usize(flags, "eval-every", 20),
        layerwise: flags.contains_key("layerwise"),
        ..Default::default()
    };
    println!(
        "gan: mode={} steps={} workers={} layerwise={}",
        mode.name(),
        cfg.steps,
        cfg.workers,
        cfg.layerwise
    );
    let mut tr = GanTrainer::new(&mut rt, cfg, NetModel::gbe()).map_err(|e| e.to_string())?;
    let rec = tr.train().map_err(|e| e.to_string())?;
    println!("  step   energy-distance (FID analog)");
    for (x, y) in &rec.get("metric").unwrap().points {
        println!("  {x:>5.0}  {y:>10.4}");
    }
    let (g, d, p, tot) = tr.phases.averages();
    println!(
        "  avg backward times: GenBP {:.2}ms DiscBP {:.2}ms PenBP {:.2}ms total {:.2}ms",
        g * 1e3,
        d * 1e3,
        p * 1e3,
        tot * 1e3
    );
    println!("  total wire bits: {}", tr.traffic.bits_sent);
    rec.to_csv(&format!("results/gan_{}.csv", tr.mode().name().to_lowercase()))
        .map_err(|e| e.to_string())?;
    Ok(())
}

fn cmd_lm(flags: &Flags) -> Result<(), String> {
    let mut rt = open_runtime()?;
    let optimizer = match flags.get("optimizer").map(|s| s.as_str()) {
        None | Some("msgd") => LmOptimizer::Msgd { momentum_pct: 90 },
        Some("qgenx") => LmOptimizer::QGenX,
        Some(o) => return Err(format!("bad --optimizer {o}")),
    };
    let mut quant = qgenx::config::QuantConfig::default();
    if let Some(m) = flags.get("mode") {
        quant.mode = QuantMode::parse(m).map_err(|e| e.to_string())?;
    }
    if let Some(spec) = flags.get("layers") {
        let parsed =
            qgenx::config::LayersConfig::parse_cli(spec).map_err(|e| e.to_string())?;
        quant.layers.names = parsed.names;
        quant.layers.bounds = parsed.bounds;
    }
    if let Some(spec) = flags.get("ef") {
        quant.ef = qgenx::config::EfConfig::parse_cli(spec).map_err(|e| e.to_string())?;
    }
    let method = match flags.get("algo") {
        Some(m) => qgenx::config::Method::parse(m).map_err(|e| e.to_string())?,
        None => qgenx::config::Method::QGenX,
    };
    if method != qgenx::config::Method::QGenX && !matches!(optimizer, LmOptimizer::QGenX) {
        return Err("--algo selects a VI method; it needs --optimizer qgenx".into());
    }
    let cfg = LmTrainConfig {
        optimizer,
        method,
        quant,
        steps: flag_usize(flags, "steps", 200),
        workers: flag_usize(flags, "workers", 3),
        eval_every: flag_usize(flags, "eval-every", 10),
        lr: flags.get("lr").and_then(|v| v.parse().ok()).unwrap_or(0.05),
        seed: 3,
    };
    let mut tr =
        LmTrainer::new(&mut rt, cfg.clone(), NetModel::gbe()).map_err(|e| e.to_string())?;
    println!(
        "lm: params={} steps={} workers={} optimizer={:?}",
        tr.param_count(),
        cfg.steps,
        cfg.workers,
        cfg.optimizer
    );
    let rec = tr.train().map_err(|e| e.to_string())?;
    println!("  step    loss");
    for (x, y) in &rec.get("loss").unwrap().points {
        println!("  {x:>5.0}  {y:>8.4}");
    }
    println!(
        "  grad time {:.1}s, comm time {:.1}s, wire bits {}",
        tr.grad_time, tr.comm_time, tr.traffic.bits_sent
    );
    rec.to_csv("results/lm_train.csv").map_err(|e| e.to_string())?;
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    let rt = open_runtime()?;
    let m = rt.manifest();
    println!("artifacts: {}", rt.artifacts_dir().display());
    println!(
        "  lm: preset={} params={} vocab={} layers={} seq={} batch={}",
        m.lm.preset, m.lm.params, m.lm.vocab, m.lm.n_layers, m.lm.seq, m.lm.batch
    );
    println!("  gan: Pg={} Pd={} batch={}", m.gan.params_g, m.gan.params_d, m.gan.batch);
    println!("  quantize kernel: d={} levels={}", m.quantize_d, m.quantize_levels);
    println!("  entries:");
    for (name, e) in &m.entries {
        let ins: Vec<String> = e.inputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        println!("    {name:<18} {} inputs {}", e.file, ins.join(" "));
    }
    Ok(())
}
