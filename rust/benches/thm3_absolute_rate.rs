//! E5 — Theorem 3: under absolute noise Q-GenX with the adaptive step-size
//! converges at `O(1/√(TK))`. Two checks:
//!
//! 1. rate in T: log-log slope of gap vs T ≈ −1/2 (ergodic average);
//! 2. speedup in K: at fixed T, error shrinks like `1/√K` — "increasing
//!    the number of processors accelerates convergence".

use qgenx::benchkit::{loglog_slope, scaled, Table};
use qgenx::config::ExperimentConfig;
use qgenx::coordinator::run_experiment;

fn cfg_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 32;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 2.0;
    cfg.algo.gamma0 = 0.3;
    cfg.quant.update_every = 200;
    cfg
}

fn mean_dist_at_t(cfg: &ExperimentConfig, seeds: u64) -> f64 {
    let mut acc = 0.0;
    for s in 0..seeds {
        let mut c = cfg.clone();
        c.seed = 1000 + s;
        let rec = run_experiment(&c).unwrap();
        acc += rec.get("dist").unwrap().last().unwrap();
    }
    acc / seeds as f64
}

fn main() {
    println!("== E5 / Theorem 3: O(1/sqrt(TK)) under absolute noise ==\n");
    let seeds = scaled(5, 2) as u64;

    // (1) rate in T
    let ts = if qgenx::benchkit::fast_mode() {
        vec![250usize, 1000]
    } else {
        vec![250usize, 500, 1000, 2000, 4000]
    };
    let mut table = Table::new(&["T", "mean dist-to-sol (ergodic)", "gap"]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &t in &ts {
        let mut cfg = cfg_base();
        cfg.iters = t;
        cfg.eval_every = t;
        cfg.workers = 2;
        let dist = mean_dist_at_t(&cfg, seeds);
        let mut c1 = cfg.clone();
        c1.seed = 1000;
        let gap = run_experiment(&c1).unwrap().get("gap").unwrap().last().unwrap();
        table.row(&[t.to_string(), format!("{dist:.5}"), format!("{gap:.5}")]);
        xs.push(t as f64);
        ys.push(dist);
    }
    table.print();
    // The ergodic average carries the early transient, which flattens the
    // finite-T slope; fit on the tail (T >= 500) where the stochastic term
    // dominates.
    let tail = xs.len().saturating_sub(4).max(0);
    let slope = loglog_slope(&xs[tail..], &ys[tail..]);
    println!("\nlog-log slope of dist vs T (tail): {slope:.3}  (Theorem 3 predicts ≈ -0.5)");
    assert!(
        slope < -0.2 && slope > -0.9,
        "rate slope {slope} outside the O(1/sqrt(T)) regime"
    );

    // (2) K-speedup at fixed T
    println!("\n-- K-scaling at T = 1500 --");
    let mut ktab = Table::new(&["K", "mean dist", "vs K=1", "1/sqrt(K) prediction"]);
    let mut base = 0.0;
    let mut kx = Vec::new();
    let mut ky = Vec::new();
    for &k in &[1usize, 2, 4, 8] {
        let mut cfg = cfg_base();
        cfg.iters = scaled(1500, 300);
        cfg.eval_every = cfg.iters;
        cfg.workers = k;
        let dist = mean_dist_at_t(&cfg, seeds);
        if k == 1 {
            base = dist;
        }
        ktab.row(&[
            k.to_string(),
            format!("{dist:.5}"),
            format!("{:.2}x", base / dist),
            format!("{:.2}x", (k as f64).sqrt()),
        ]);
        kx.push(k as f64);
        ky.push(dist);
    }
    ktab.print();
    let kslope = loglog_slope(&kx, &ky);
    println!("\nlog-log slope of dist vs K: {kslope:.3}  (Theorem 3 predicts ≈ -0.5)");
    assert!(ky[3] < ky[0], "K=8 must beat K=1");

    qgenx::benchkit::write_csv(
        "results/thm3_rate.csv",
        &["T", "dist"],
        &xs.iter().zip(ys.iter()).map(|(x, y)| vec![x.to_string(), y.to_string()]).collect::<Vec<_>>(),
    )
    .unwrap();
    println!("csv -> results/thm3_rate.csv");
}
