//! Length-framed wire messages for the socket transport.
//!
//! Every message on a [`crate::net::SocketTransport`] connection is one
//! frame: a fixed 24-byte header followed by `len` payload bytes. The
//! payload of a [`FrameKind::Data`] frame is exactly the docs/WIRE.md
//! encoded payload (data wire v1/v2, stat wire v1–v3) — framing adds
//! transport envelope, never touches the encoded formats.
//!
//! Header layout (all little-endian, matching the WIRE.md convention):
//!
//! | offset | size | field   | meaning                                   |
//! |--------|------|---------|-------------------------------------------|
//! | 0      | 4    | magic   | `0x584E4751` (`b"QGNX"` read as LE u32)   |
//! | 4      | 2    | version | frame protocol version, currently `1`     |
//! | 6      | 1    | kind    | [`FrameKind`] discriminant                |
//! | 7      | 1    | flags   | reserved, must be `0`                     |
//! | 8      | 4    | rank    | sender's rank                             |
//! | 12     | 8    | round   | sender's round counter (lockstep check)   |
//! | 20     | 4    | len     | payload length in bytes                   |
//!
//! The header is deliberately self-checking: magic/version reject
//! cross-protocol garbage, `kind` + `round` give every receiver a free
//! lockstep assertion (all ranks must be in the same round of the same
//! plane), and `len` is bounded by [`MAX_FRAME_PAYLOAD`] so a corrupt
//! header cannot trigger a multi-gigabyte allocation.

use crate::error::{Error, Result};
use std::io::{Read, Write};

/// `b"QGNX"` interpreted as a little-endian u32.
pub const FRAME_MAGIC: u32 = 0x584E_4751;

/// Current frame protocol version.
pub const FRAME_VERSION: u16 = 1;

/// Fixed header size in bytes.
pub const FRAME_HEADER_LEN: usize = 24;

/// Upper bound on a single frame payload (1 GiB). Real payloads are
/// kilobytes; this only exists to bound allocation on a corrupt header.
pub const MAX_FRAME_PAYLOAD: usize = 1 << 30;

/// What a frame carries. Handshake kinds (`Hello`/`Welcome`/`Peer`) appear
/// only during connection setup; `Data`/`Control`/`Oob` mirror
/// [`crate::net::Plane`] for exchange rounds; `Goodbye`/`Abort` end a
/// connection cleanly or with a poison reason.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Worker → rank 0 rendezvous: "rank R of a group of K, my peer
    /// listener is at ADDR".
    Hello = 0,
    /// Rank 0 → worker: the full peer directory once everyone arrived.
    Welcome = 1,
    /// Worker → worker mesh link identification after dialing.
    Peer = 2,
    /// Data-plane exchange payload ([`crate::net::Plane::Data`]).
    Data = 3,
    /// Clean shutdown; payload empty.
    Goodbye = 4,
    /// Group poisoned; payload is the UTF-8 reason.
    Abort = 5,
    /// Control-plane exchange payload ([`crate::net::Plane::Control`]).
    Control = 6,
    /// Out-of-band exchange payload ([`crate::net::Plane::Oob`]).
    Oob = 7,
}

impl FrameKind {
    /// The frame kind carrying an exchange round of the given plane.
    pub fn for_plane(plane: crate::net::Plane) -> FrameKind {
        match plane {
            crate::net::Plane::Data => FrameKind::Data,
            crate::net::Plane::Control => FrameKind::Control,
            crate::net::Plane::Oob => FrameKind::Oob,
        }
    }

    pub fn from_u8(v: u8) -> Result<FrameKind> {
        Ok(match v {
            0 => FrameKind::Hello,
            1 => FrameKind::Welcome,
            2 => FrameKind::Peer,
            3 => FrameKind::Data,
            4 => FrameKind::Goodbye,
            5 => FrameKind::Abort,
            6 => FrameKind::Control,
            7 => FrameKind::Oob,
            _ => return Err(Error::Net(format!("unknown frame kind {v}"))),
        })
    }
}

/// Decoded frame header. `len` is carried separately by [`read_frame`];
/// the header keeps only the fields receivers validate against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: FrameKind,
    pub rank: u32,
    pub round: u64,
    pub len: u32,
}

impl FrameHeader {
    pub fn encode(&self) -> [u8; FRAME_HEADER_LEN] {
        let mut h = [0u8; FRAME_HEADER_LEN];
        h[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        h[4..6].copy_from_slice(&FRAME_VERSION.to_le_bytes());
        h[6] = self.kind as u8;
        h[7] = 0; // flags, reserved
        h[8..12].copy_from_slice(&self.rank.to_le_bytes());
        h[12..20].copy_from_slice(&self.round.to_le_bytes());
        h[20..24].copy_from_slice(&self.len.to_le_bytes());
        h
    }

    pub fn decode(h: &[u8; FRAME_HEADER_LEN]) -> Result<FrameHeader> {
        let magic = u32::from_le_bytes(h[0..4].try_into().expect("4 bytes"));
        if magic != FRAME_MAGIC {
            return Err(Error::Net(format!(
                "bad frame magic {magic:#010x} (expected {FRAME_MAGIC:#010x}) — \
                 not a qgenx transport stream"
            )));
        }
        let version = u16::from_le_bytes(h[4..6].try_into().expect("2 bytes"));
        if version != FRAME_VERSION {
            return Err(Error::Net(format!(
                "unsupported frame version {version} (this build speaks {FRAME_VERSION})"
            )));
        }
        let kind = FrameKind::from_u8(h[6])?;
        if h[7] != 0 {
            return Err(Error::Net(format!("reserved frame flags set: {:#04x}", h[7])));
        }
        let rank = u32::from_le_bytes(h[8..12].try_into().expect("4 bytes"));
        let round = u64::from_le_bytes(h[12..20].try_into().expect("8 bytes"));
        let len = u32::from_le_bytes(h[20..24].try_into().expect("4 bytes"));
        if len as usize > MAX_FRAME_PAYLOAD {
            return Err(Error::Net(format!(
                "frame payload of {len} bytes exceeds the {MAX_FRAME_PAYLOAD}-byte cap \
                 — corrupt header?"
            )));
        }
        Ok(FrameHeader { kind, rank, round, len })
    }
}

/// Write one frame (header + payload) to `w`. IO failures surface as
/// [`Error::Net`] with the peer context baked in by the caller's `what`.
pub fn write_frame(
    w: &mut impl Write,
    kind: FrameKind,
    rank: u32,
    round: u64,
    payload: &[u8],
) -> Result<()> {
    if payload.len() > MAX_FRAME_PAYLOAD {
        return Err(Error::Net(format!(
            "refusing to send a {}-byte frame payload (cap {MAX_FRAME_PAYLOAD})",
            payload.len()
        )));
    }
    let hdr = FrameHeader { kind, rank, round, len: payload.len() as u32 };
    let h = hdr.encode();
    w.write_all(&h).map_err(|e| Error::Net(format!("writing frame header: {e}")))?;
    w.write_all(payload).map_err(|e| Error::Net(format!("writing frame payload: {e}")))?;
    w.flush().map_err(|e| Error::Net(format!("flushing frame: {e}")))?;
    Ok(())
}

/// Read exactly one frame header from `r`.
pub fn read_header(r: &mut impl Read) -> Result<FrameHeader> {
    let mut h = [0u8; FRAME_HEADER_LEN];
    r.read_exact(&mut h).map_err(|e| Error::Net(format!("reading frame header: {e}")))?;
    FrameHeader::decode(&h)
}

/// Read one full frame: header, then its `len` payload bytes.
pub fn read_frame(r: &mut impl Read) -> Result<(FrameHeader, Vec<u8>)> {
    let hdr = read_header(r)?;
    let mut payload = vec![0u8; hdr.len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| Error::Net(format!("reading {}-byte frame payload: {e}", hdr.len)))?;
    Ok((hdr, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrips_through_encode_decode() {
        let hdr = FrameHeader { kind: FrameKind::Data, rank: 3, round: 0xDEAD_BEEF_01, len: 4096 };
        let decoded = FrameHeader::decode(&hdr.encode()).unwrap();
        assert_eq!(decoded, hdr);
    }

    #[test]
    fn frame_roundtrips_through_a_byte_stream() {
        let mut buf = Vec::new();
        let payload = vec![0xAB; 17];
        write_frame(&mut buf, FrameKind::Control, 2, 9, &payload).unwrap();
        assert_eq!(buf.len(), FRAME_HEADER_LEN + 17);
        let (hdr, got) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(hdr.kind, FrameKind::Control);
        assert_eq!(hdr.rank, 2);
        assert_eq!(hdr.round, 9);
        assert_eq!(got, payload);
        // Empty payloads (Goodbye) also roundtrip.
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Goodbye, 0, 0, &[]).unwrap();
        let (hdr, got) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(hdr.kind, FrameKind::Goodbye);
        assert!(got.is_empty());
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let hdr = FrameHeader { kind: FrameKind::Hello, rank: 0, round: 0, len: 0 };
        let mut h = hdr.encode();
        h[0] ^= 0xFF;
        let err = FrameHeader::decode(&h).expect_err("bad magic");
        assert!(err.to_string().contains("magic"), "got: {err}");

        let mut h = hdr.encode();
        h[4] = 0xFE; // version 0x__FE
        let err = FrameHeader::decode(&h).expect_err("bad version");
        assert!(err.to_string().contains("version"), "got: {err}");

        let mut h = hdr.encode();
        h[6] = 200; // unknown kind
        let err = FrameHeader::decode(&h).expect_err("bad kind");
        assert!(err.to_string().contains("kind"), "got: {err}");

        let mut h = hdr.encode();
        h[7] = 1; // reserved flags
        let err = FrameHeader::decode(&h).expect_err("reserved flags");
        assert!(err.to_string().contains("flags"), "got: {err}");
    }

    #[test]
    fn truncated_streams_error_instead_of_hanging() {
        // Truncated header.
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Data, 1, 1, &[1, 2, 3]).unwrap();
        let short = &buf[..FRAME_HEADER_LEN - 5];
        let err = read_frame(&mut &short[..]).expect_err("short header");
        assert!(err.to_string().contains("header"), "got: {err}");
        // Truncated payload.
        let short = &buf[..FRAME_HEADER_LEN + 1];
        let err = read_frame(&mut &short[..]).expect_err("short payload");
        assert!(err.to_string().contains("payload"), "got: {err}");
    }

    #[test]
    fn oversized_len_is_rejected_before_allocation() {
        let hdr = FrameHeader { kind: FrameKind::Data, rank: 0, round: 0, len: 0 };
        let mut h = hdr.encode();
        h[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = FrameHeader::decode(&h).expect_err("oversized");
        assert!(err.to_string().contains("cap"), "got: {err}");
    }

    #[test]
    fn fuzzed_headers_and_streams_never_panic_or_over_read() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from(0xF4A2_2E01);

        // (1) Random byte soup as a header: decode must return Ok or a
        // structured error — never panic. Random magic almost never
        // matches, so also exercise the deeper checks by starting from a
        // valid header and flipping random bytes.
        for trial in 0..2000u32 {
            let mut h = [0u8; FRAME_HEADER_LEN];
            if trial % 2 == 0 {
                for b in h.iter_mut() {
                    *b = rng.next_u64() as u8;
                }
            } else {
                let hdr = FrameHeader {
                    kind: FrameKind::Data,
                    rank: rng.next_u64() as u32,
                    round: rng.next_u64(),
                    len: (rng.below(MAX_FRAME_PAYLOAD as u64 + 1)) as u32,
                };
                h = hdr.encode();
                let flips = 1 + rng.below(3) as usize;
                for _ in 0..flips {
                    let at = rng.below(FRAME_HEADER_LEN as u64) as usize;
                    h[at] ^= (rng.next_u64() as u8) | 1;
                }
            }
            if let Ok(hdr) = FrameHeader::decode(&h) {
                // Anything decode accepts must satisfy its own invariants.
                assert!(hdr.len as usize <= MAX_FRAME_PAYLOAD);
                assert_eq!(FrameKind::from_u8(hdr.kind as u8).unwrap(), hdr.kind);
            }
        }

        // (2) Random truncations/extensions of a valid frame stream: the
        // reader must consume at most one frame's bytes, never hang on a
        // finite cursor, and return structured errors for short input.
        let payload: Vec<u8> = (0..257u32).map(|i| i as u8).collect();
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Oob, 7, 41, &payload).unwrap();
        for _ in 0..500 {
            let cut = rng.below(wire.len() as u64 + 1) as usize;
            let mut cursor = &wire[..cut];
            match read_frame(&mut cursor) {
                Ok((hdr, got)) => {
                    assert_eq!(cut, wire.len(), "a partial stream must not parse");
                    assert_eq!((hdr.kind, hdr.rank, hdr.round), (FrameKind::Oob, 7, 41));
                    assert_eq!(got, payload);
                }
                Err(e) => {
                    let msg = e.to_string();
                    assert!(
                        msg.contains("header") || msg.contains("payload"),
                        "structured error expected, got: {msg}"
                    );
                }
            }
            // Over-read check: the cursor advanced by at most one frame.
            assert!(wire[..cut].len() - cursor.len() <= FRAME_HEADER_LEN + payload.len());
        }

        // (3) Corrupt `len` fields over a real payload: the reader either
        // errors or returns exactly the advertised bytes — bounded by the
        // cap, so a corrupt header cannot force a giant allocation.
        for _ in 0..200 {
            let mut bad = wire.clone();
            let fake_len = rng.next_u64() as u32;
            bad[20..24].copy_from_slice(&fake_len.to_le_bytes());
            match read_frame(&mut bad.as_slice()) {
                Ok((hdr, got)) => {
                    assert_eq!(got.len(), hdr.len as usize);
                    assert!(got.len() <= payload.len());
                }
                Err(e) => {
                    let msg = e.to_string();
                    assert!(
                        msg.contains("cap") || msg.contains("payload"),
                        "structured error expected, got: {msg}"
                    );
                }
            }
        }
    }

    #[test]
    fn kinds_map_planes_and_roundtrip_u8() {
        use crate::net::Plane;
        assert_eq!(FrameKind::for_plane(Plane::Data), FrameKind::Data);
        assert_eq!(FrameKind::for_plane(Plane::Control), FrameKind::Control);
        assert_eq!(FrameKind::for_plane(Plane::Oob), FrameKind::Oob);
        for k in [
            FrameKind::Hello,
            FrameKind::Welcome,
            FrameKind::Peer,
            FrameKind::Data,
            FrameKind::Goodbye,
            FrameKind::Abort,
            FrameKind::Control,
            FrameKind::Oob,
        ] {
            assert_eq!(FrameKind::from_u8(k as u8).unwrap(), k);
        }
        assert!(FrameKind::from_u8(99).is_err());
    }
}
