//! The random quantization function `Q_ℓ` of Definition 1.
//!
//! `Q_ℓ(v) = ‖v‖_q · s ⊙ [q_ℓ(u_1), …, q_ℓ(u_d)]` where `u_i = |v_i|/‖v‖_q`
//! and `q_ℓ(u)` rounds `u` to the bracketing level below with probability
//! `1 − ξ(u)` and above with probability `ξ(u)`,
//! `ξ(u) = (u − ℓ_τ)/(ℓ_{τ+1} − ℓ_τ)` — which makes `E[Q_ℓ(v)] = v` exactly
//! (unbiasedness, Theorem 1).
//!
//! The stochastic core is factored as a *pure function of explicit
//! uniforms* ([`quantize_with_uniforms`]) so the Rust hot path and the
//! Pallas L1 kernel can be tested for **bit-exact** agreement, not merely
//! statistical agreement (DESIGN.md §5.3).
//!
//! Bucketing: torch_cgx-style — the vector is split into independent
//! buckets of `bucket_size` coordinates, each with its own norm. This
//! bounds the dynamic range per bucket and is what the paper's experiments
//! use (bucket size 1024).

use super::levels::Levels;
use crate::error::{Error, Result};
use crate::util::{norm_q, Rng};

/// A quantized dual vector: per-bucket norms + per-coordinate level symbols
/// and signs. `symbols[i] ∈ 0..=s+1` indexes into the level sequence.
///
/// `Default` is the empty arena: the `_into` functions
/// ([`quantize_into`], [`crate::quant::decode_vector_into`]) clear and
/// refill one of these in place, so a long-lived instance never
/// reallocates in steady state.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct QuantizedVector {
    /// Original dimension d.
    pub d: usize,
    /// Bucket size B (d for whole-vector quantization).
    pub bucket_size: usize,
    /// One `L^q` norm per bucket (`ceil(d / B)` of them).
    pub norms: Vec<f32>,
    /// Level index per coordinate.
    pub symbols: Vec<u16>,
    /// Sign bit per coordinate (true = negative), packed 64 per word.
    pub sign_words: Vec<u64>,
}

impl QuantizedVector {
    pub fn num_buckets(&self) -> usize {
        self.norms.len()
    }

    /// Reset to dimension `d` / bucket size `b` with all symbols, signs and
    /// norms cleared, reusing the existing allocations.
    pub(crate) fn reset(&mut self, d: usize, b: usize) {
        self.d = d;
        self.bucket_size = b;
        self.norms.clear();
        self.norms.reserve(d.div_ceil(b.max(1)));
        self.symbols.clear();
        self.symbols.resize(d, 0);
        self.sign_words.clear();
        self.sign_words.resize(d.div_ceil(64), 0);
    }

    #[inline]
    pub fn sign_is_neg(&self, i: usize) -> bool {
        (self.sign_words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    fn set_sign(sign_words: &mut [u64], i: usize, neg: bool) {
        if neg {
            sign_words[i / 64] |= 1u64 << (i % 64);
        }
    }

    /// Count of zero symbols (maps to `p_0` mass of Theorem 2).
    pub fn num_zeros(&self) -> usize {
        self.symbols.iter().filter(|&&s| s == 0).count()
    }
}

/// Quantize `v` with fresh randomness from `rng`.
///
/// `q` is the norm exponent (`u32::MAX` = L∞); `bucket_size = 0` means one
/// bucket spanning the whole vector.
pub fn quantize(
    v: &[f32],
    levels: &Levels,
    q: u32,
    bucket_size: usize,
    rng: &mut Rng,
) -> Result<QuantizedVector> {
    let mut out = QuantizedVector::default();
    quantize_into(v, levels, q, bucket_size, rng, &mut out)?;
    Ok(out)
}

/// [`quantize`] into a reusable arena: identical RNG consumption and
/// output, zero allocations once `out`'s buffers have grown to `d`. The
/// compressor hot path lives here.
pub fn quantize_into(
    v: &[f32],
    levels: &Levels,
    q: u32,
    bucket_size: usize,
    rng: &mut Rng,
    out: &mut QuantizedVector,
) -> Result<()> {
    // §Perf: uniforms are drawn inline per coordinate — materializing a
    // d-sized temp costs ~2 extra memory passes at model scale.
    quantize_core(v, levels, q, bucket_size, |_| rng.uniform_f32(), out)
}

/// Deterministic quantization given explicit uniforms (one per coordinate).
/// This is the function the Pallas kernel implements; equality tests
/// between the two layers go through here.
pub fn quantize_with_uniforms(
    v: &[f32],
    levels: &Levels,
    q: u32,
    bucket_size: usize,
    uniforms: &[f32],
) -> Result<QuantizedVector> {
    if uniforms.len() != v.len() {
        return Err(Error::Quant(format!(
            "need one uniform per coordinate: {} vs {}",
            uniforms.len(),
            v.len()
        )));
    }
    let mut out = QuantizedVector::default();
    quantize_core(v, levels, q, bucket_size, |i| uniforms[i], &mut out)?;
    Ok(out)
}

/// Shared implementation over a per-coordinate uniform source
/// (monomorphized per caller — no indirect call in the inner loop),
/// filling a caller-owned arena.
#[inline]
fn quantize_core<F: FnMut(usize) -> f32>(
    v: &[f32],
    levels: &Levels,
    q: u32,
    bucket_size: usize,
    mut uniform_at: F,
    out: &mut QuantizedVector,
) -> Result<()> {
    if v.is_empty() {
        return Err(Error::Quant("cannot quantize an empty vector".into()));
    }
    let d = v.len();
    let b = if bucket_size == 0 { d } else { bucket_size };
    let nb = d.div_ceil(b);
    out.reset(d, b);
    let norms = &mut out.norms;
    let symbols = &mut out.symbols;
    let sign_words = &mut out.sign_words;

    for bi in 0..nb {
        let lo = bi * b;
        let hi = ((bi + 1) * b).min(d);
        let bucket = &v[lo..hi];
        let norm = norm_q(bucket, q);
        norms.push(norm as f32);
        if norm == 0.0 {
            continue; // all-zero bucket: symbols stay 0
        }
        // §Perf: the whole inner loop runs in f32 (same dtype as the Pallas
        // kernel — strengthens cross-layer parity) with an O(1) bin index
        // for uniform levels and an O(log s) search otherwise.
        let inv = (1.0 / norm) as f32;
        let table = levels.table_f32();
        let s = levels.s();
        if let Some(denom) = levels.uniform_denom() {
            // tau = floor(u * (s+1)); xi = frac(u * (s+1)).
            for i in lo..hi {
                let x = v[i];
                let u = (x.abs() * inv).min(1.0);
                let pos = u * denom;
                let t = (pos as usize).min(s);
                let xi = pos - t as f32;
                let up = uniform_at(i) < xi;
                let sym = t + up as usize;
                symbols[i] = sym as u16;
                QuantizedVector::set_sign(sign_words, i, sym != 0 && x < 0.0);
            }
        } else {
            for i in lo..hi {
                let x = v[i];
                let u = (x.abs() * inv).min(1.0);
                // partition point over the f32 table's interior entries
                let t = if u >= 1.0 {
                    s
                } else {
                    table[1..=s].partition_point(|&l| l <= u)
                };
                let lo_l = table[t];
                let hi_l = table[t + 1];
                let xi = if hi_l > lo_l { (u - lo_l) / (hi_l - lo_l) } else { 0.0 };
                let up = uniform_at(i) < xi;
                let sym = t + up as usize;
                symbols[i] = sym as u16;
                // Signs are canonical: only nonzero symbols carry one (the
                // wire sends no sign for zeros — Lemma 3).
                QuantizedVector::set_sign(sign_words, i, sym != 0 && x < 0.0);
            }
        }
    }
    Ok(())
}

/// Reconstruct the (still unbiased) dequantized vector
/// `‖v‖_q · s_i · ℓ_{symbols[i]}` per bucket.
pub fn dequantize(qv: &QuantizedVector, levels: &Levels) -> Vec<f32> {
    let mut out = vec![0.0f32; qv.d];
    dequantize_into(qv, levels, &mut out);
    out
}

/// In-place variant used on the hot path to avoid allocation.
pub fn dequantize_into(qv: &QuantizedVector, levels: &Levels, out: &mut [f32]) {
    assert_eq!(out.len(), qv.d);
    let b = qv.bucket_size;
    let table = levels.table_f32();
    for (bi, &norm) in qv.norms.iter().enumerate() {
        let lo = bi * b;
        let hi = ((bi + 1) * b).min(qv.d);
        if norm == 0.0 {
            out[lo..hi].fill(0.0);
            continue;
        }
        for i in lo..hi {
            // §Perf: f32 table lookup + branchless sign application.
            let mag = norm * table[qv.symbols[i] as usize];
            let sign_bit = ((qv.sign_words[i / 64] >> (i % 64)) & 1) as u32;
            out[i] = f32::from_bits(mag.to_bits() ^ (sign_bit << 31));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{assert_close, forall};
    use crate::util::{dist_sq, norm2_sq, Rng};

    fn roundtrip_dim(qv: &QuantizedVector, levels: &Levels) -> Vec<f32> {
        dequantize(qv, levels)
    }

    #[test]
    fn exact_level_values_are_fixed_points() {
        // v whose normalized coords all sit exactly on levels -> Q(v) = v
        // regardless of the uniforms.
        let levels = Levels::uniform(3); // 0, .25, .5, .75, 1
        let v = [1.0f32, -0.75, 0.5, 0.25, 0.0];
        // L_inf norm = 1 so u = |v|.
        for trial in 0..20 {
            let mut rng = Rng::seed_from(trial);
            let qv = quantize(&v, &levels, u32::MAX, 0, &mut rng).unwrap();
            let back = roundtrip_dim(&qv, &levels);
            for (a, b) in v.iter().zip(back.iter()) {
                assert!((a - b).abs() < 1e-6, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn unbiasedness_montecarlo() {
        let levels = Levels::uniform(4);
        let mut rng = Rng::seed_from(7);
        let v: Vec<f32> = rng.gaussian_vec(32, 1.0);
        let trials = 20_000;
        let mut mean = vec![0.0f64; v.len()];
        for _ in 0..trials {
            let qv = quantize(&v, &levels, 2, 0, &mut rng).unwrap();
            let back = dequantize(&qv, &levels);
            for (m, b) in mean.iter_mut().zip(back.iter()) {
                *m += *b as f64;
            }
        }
        let norm = crate::util::norm2(&v);
        for (m, x) in mean.iter().zip(v.iter()) {
            let est = m / trials as f64;
            // per-coordinate tolerance ~ 4 sigma of the MC error; coordinate
            // variance is bounded by (norm * bin_width/2)^2.
            let tol = 4.0 * 0.5 * norm / (trials as f64).sqrt() + 1e-3;
            assert!(
                (est - *x as f64).abs() < tol,
                "biased coordinate: est {est} true {x} tol {tol}"
            );
        }
    }

    #[test]
    fn variance_matches_analytic_per_coordinate() {
        // E[(q(u)-u)^2] = (hi-u)(u-lo) for a single coordinate.
        let levels = Levels::uniform(1); // levels 0, 0.5, 1
        let u = 0.3f32;
        let v = [u, 1.0]; // second coord pins Linf norm to 1
        let mut rng = Rng::seed_from(3);
        let trials = 200_000;
        let mut sq = 0.0f64;
        for _ in 0..trials {
            let qv = quantize(&v, &levels, u32::MAX, 0, &mut rng).unwrap();
            let back = dequantize(&qv, &levels);
            let e = back[0] as f64 - u as f64;
            sq += e * e;
        }
        let emp = sq / trials as f64;
        let analytic = (0.5 - 0.3) * (0.3 - 0.0);
        assert_close(emp, analytic, 5e-4);
    }

    #[test]
    fn zero_vector_quantizes_to_zero() {
        let levels = Levels::uniform(3);
        let v = [0.0f32; 16];
        let mut rng = Rng::seed_from(1);
        let qv = quantize(&v, &levels, 2, 4, &mut rng).unwrap();
        assert!(dequantize(&qv, &levels).iter().all(|&x| x == 0.0));
        assert_eq!(qv.num_zeros(), 16);
    }

    #[test]
    fn bucketing_isolates_norms() {
        let levels = Levels::uniform(3);
        // First bucket tiny values, second bucket huge: with one global norm
        // the tiny bucket would collapse to 0/ℓ1; with buckets it survives.
        let mut v = vec![0.001f32; 4];
        v.extend_from_slice(&[1000.0f32; 4]);
        let mut rng = Rng::seed_from(5);
        let qv = quantize(&v, &levels, 2, 4, &mut rng).unwrap();
        assert_eq!(qv.num_buckets(), 2);
        assert!(qv.norms[0] < 1.0 && qv.norms[1] > 100.0);
        let back = dequantize(&qv, &levels);
        // Relative error within the first bucket is bounded by its own norm.
        for i in 0..4 {
            assert!(back[i].abs() <= qv.norms[0] * 1.0 + 1e-9);
        }
    }

    #[test]
    fn quantize_into_matches_quantize_and_reuses_buffers() {
        let levels = Levels::uniform(14);
        let mut rng_a = Rng::seed_from(42);
        let mut rng_b = Rng::seed_from(42);
        let mut arena = QuantizedVector::default();
        let mut rng_v = Rng::seed_from(43);
        for _ in 0..4 {
            let v = rng_v.gaussian_vec(300, 1.0);
            let fresh = quantize(&v, &levels, 2, 64, &mut rng_a).unwrap();
            quantize_into(&v, &levels, 2, 64, &mut rng_b, &mut arena).unwrap();
            assert_eq!(fresh, arena, "arena fill must be bit-identical (incl. RNG stream)");
        }
        // Steady state: refilling at the same d must not reallocate.
        let symbols_ptr = arena.symbols.as_ptr();
        let v = rng_v.gaussian_vec(300, 1.0);
        quantize_into(&v, &levels, 2, 64, &mut rng_b, &mut arena).unwrap();
        assert_eq!(arena.symbols.as_ptr(), symbols_ptr);
        // Stale state from a larger previous message must not leak into a
        // smaller one (symbols/signs cleared by reset).
        let small = [0.0f32, -1.0];
        quantize_into(&small, &levels, 2, 0, &mut rng_b, &mut arena).unwrap();
        assert_eq!(arena.d, 2);
        assert_eq!(arena.symbols.len(), 2);
        assert_eq!(arena.sign_words.len(), 1);
        assert_eq!(arena.num_zeros(), 1);
    }

    #[test]
    fn deterministic_with_explicit_uniforms() {
        let levels = Levels::exponential(4);
        let mut rng = Rng::seed_from(11);
        let v = rng.gaussian_vec(100, 2.0);
        let uniforms = rng.uniform_vec(100);
        let a = quantize_with_uniforms(&v, &levels, 2, 32, &uniforms).unwrap();
        let b = quantize_with_uniforms(&v, &levels, 2, 32, &uniforms).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn uniform_below_xi_rounds_up() {
        // u = 0.3 with levels {0,0.5,1}: xi = 0.6. uniform 0.59 -> up (0.5),
        // uniform 0.61 -> down (0).
        let levels = Levels::uniform(1);
        let v = [0.3f32, 1.0];
        let up = quantize_with_uniforms(&v, &levels, u32::MAX, 0, &[0.59, 0.0]).unwrap();
        assert_eq!(up.symbols[0], 1);
        let down = quantize_with_uniforms(&v, &levels, u32::MAX, 0, &[0.61, 0.0]).unwrap();
        assert_eq!(down.symbols[0], 0);
    }

    #[test]
    fn l1_and_l2_norms_supported() {
        let levels = Levels::uniform(7);
        let mut rng = Rng::seed_from(13);
        let v = rng.gaussian_vec(64, 1.0);
        for q in [1u32, 2, 3, u32::MAX] {
            let qv = quantize(&v, &levels, q, 0, &mut rng).unwrap();
            let back = dequantize(&qv, &levels);
            // Sanity: the per-draw error stays within a few multiples of the
            // Theorem 1 variance factor for this normalization.
            let err = dist_sq(&v, &back);
            let eps = crate::quant::bounds::epsilon_q(&levels, v.len(), q).max(1.0);
            assert!(err < 4.0 * eps * norm2_sq(&v), "q={q} err {err} eps {eps}");
        }
    }

    #[test]
    fn error_paths() {
        let levels = Levels::uniform(3);
        assert!(quantize_with_uniforms(&[], &levels, 2, 0, &[]).is_err());
        assert!(quantize_with_uniforms(&[1.0], &levels, 2, 0, &[0.5, 0.5]).is_err());
    }

    #[test]
    fn prop_symbols_in_alphabet_and_signs_match() {
        forall("quantizer invariants", 100, |g| {
            let s = g.usize_in(1, 30);
            let levels = Levels::new(g.levels(s)).unwrap();
            let d = g.usize_in(1, 300);
            let v = g.f32_vec(d, -5.0, 5.0);
            let bucket = *g.choose(&[0usize, 7, 64, 1024]);
            let uniforms: Vec<f32> = (0..d).map(|_| g.f32_in(0.0, 1.0)).collect();
            let q = *g.choose(&[1u32, 2, u32::MAX]);
            let qv = quantize_with_uniforms(&v, &levels, q, bucket, &uniforms).unwrap();
            for (i, &sym) in qv.symbols.iter().enumerate() {
                assert!((sym as usize) < levels.alphabet_size());
                if v[i] < 0.0 && sym != 0 {
                    assert!(qv.sign_is_neg(i), "negative coord must keep sign");
                }
            }
            // Reconstruction magnitude never exceeds the bucket norm.
            let back = dequantize(&qv, &levels);
            let b = if bucket == 0 { d } else { bucket };
            for (i, &x) in back.iter().enumerate() {
                let nb = qv.norms[i / b];
                assert!(x.abs() <= nb * 1.0 + 1e-5);
            }
        });
    }

    #[test]
    fn prop_quantization_error_bounded_by_theorem1() {
        use crate::quant::bounds::epsilon_q;
        forall("thm1 per-draw usually holds in expectation", 30, |g| {
            let s = g.usize_in(1, 15);
            let levels = Levels::uniform(s);
            let d = g.usize_in(4, 128);
            let v = g.gaussian_vec(d, 1.0);
            if crate::util::norm2_sq(&v) == 0.0 {
                return;
            }
            // Empirical E over 300 draws.
            let mut rng = Rng::seed_from(g.case as u64 + 99);
            let mut acc = 0.0;
            let trials = 300;
            for _ in 0..trials {
                let qv = quantize(&v, &levels, 2, 0, &mut rng).unwrap();
                let back = dequantize(&qv, &levels);
                acc += dist_sq(&v, &back);
            }
            let emp = acc / trials as f64;
            let bound = epsilon_q(&levels, d, 2) * norm2_sq(&v);
            // Allow 20% MC slack.
            assert!(emp <= bound * 1.2 + 1e-9, "emp {emp} > bound {bound}");
        });
    }
}
