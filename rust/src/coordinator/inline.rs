//! One-shot inline entry points: the in-process (loopback) execution of
//! Algorithm 1, packaged as run-to-completion functions.
//!
//! These are thin wrappers over [`crate::coordinator::Session`] — the
//! steppable run engine that owns all `K` oracles and compression
//! endpoints in one thread. The wrappers exist for the benches and CLI
//! (thousands of sweep runs want a one-liner) and as the compatibility
//! surface of the seed API: their trajectories and wire accounting are
//! bit-identical to the pre-Session runners (regression-tested against a
//! frozen copy of the seed loops in `tests/session_parity.rs`).
//!
//! The config selects one of three runner families (now
//! `ExchangePolicy` implementations — see `coordinator::policy`):
//!
//! * **exact** — per-step dual exchange over an exact topology, the
//!   seed's Algorithm 1;
//! * **gossip** — inexact topologies: per-step dual exchange averaged
//!   over graph neighborhoods, plus `consensus_dist`;
//! * **local** (`local.steps ≥ 2`) — private extra-gradient iterations
//!   between syncs, quantized model-delta averaging at syncs.
//!
//! `local.steps = 1` deliberately does *not* engage the delta-sync
//! machinery: with one local step the algorithm communicates every
//! iteration anyway, and the per-step dual exchange is the trajectory the
//! paper's theorems describe — so it runs the exact (or gossip) family,
//! bit-for-bit identical to the seed.

use super::session::{Algorithm, Session};
use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::metrics::Recorder;

/// Run one Q-GenX experiment per the config; returns the metric recorder
/// with series `gap`, `dist`, `residual`, `gamma`, `bits_cum`,
/// `sim_time_cum` and summary scalars. Equivalent to
/// `Session::builder(cfg.clone()).build()?.run()` — build a [`Session`]
/// directly to observe the run mid-flight, stop it early, or checkpoint
/// it (`docs/API.md`).
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Recorder> {
    Session::builder(cfg.clone()).build()?.run()
}

/// QSGDA baseline (Beznosikov et al. 2022): quantized SGDA with γ_t = γ₀/√t,
/// same oracles/compressors/network — only the update rule differs
/// (no extrapolation, no adaptive step). The Figure-4 comparator, folded
/// into the session engine as an algorithm policy
/// ([`Algorithm::Sgda`]); always accounted as a full-mesh round.
pub fn run_qsgda_baseline(cfg: &ExperimentConfig) -> Result<Recorder> {
    Session::builder(cfg.clone()).algorithm(Algorithm::Sgda).build()?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LevelScheme, QuantMode, Variant};

    fn base_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 3;
        cfg.iters = 400;
        cfg.eval_every = 100;
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 16;
        cfg.problem.noise = "absolute".into();
        cfg.problem.sigma = 0.3;
        cfg.quant.update_every = 100;
        cfg
    }

    #[test]
    fn qgenx_converges_quantized_absolute_noise() {
        let cfg = base_cfg();
        let rec = run_experiment(&cfg).unwrap();
        let gaps = rec.get("gap").unwrap();
        let first = gaps.points.first().unwrap().1;
        let last = gaps.last().unwrap();
        assert!(last < first, "gap should shrink: {first} -> {last}");
        assert!(rec.scalar("total_bits").unwrap() > 0.0);
        assert!(rec.scalar("level_updates").unwrap() >= 1.0);
    }

    #[test]
    fn fp32_and_quantized_converge_similarly_but_quantized_sends_fewer_bits() {
        let mut cfg = base_cfg();
        cfg.iters = 600;
        let rec_q = run_experiment(&cfg).unwrap();
        cfg.quant.mode = QuantMode::Fp32;
        let rec_f = run_experiment(&cfg).unwrap();
        let bits_q = rec_q.scalar("total_bits").unwrap();
        let bits_f = rec_f.scalar("total_bits").unwrap();
        assert!(bits_q < bits_f / 3.0, "quantized {bits_q} vs fp32 {bits_f}");
        // Both reach a small gap.
        let gq = rec_q.get("gap").unwrap().last().unwrap();
        let gf = rec_f.get("gap").unwrap().last().unwrap();
        assert!(gq < 1.0 && gf < 1.0, "gq={gq} gf={gf}");
    }

    #[test]
    fn all_variants_run_and_converge() {
        for v in [Variant::DualAveraging, Variant::DualExtrapolation, Variant::OptimisticDualAveraging] {
            let mut cfg = base_cfg();
            cfg.algo.variant = v;
            cfg.iters = 500;
            let rec = run_experiment(&cfg).unwrap();
            let last = rec.get("gap").unwrap().last().unwrap();
            assert!(last.is_finite(), "variant {v:?} gap {last}");
        }
    }

    #[test]
    fn da_and_optda_send_half_the_rounds_of_de() {
        let mut cfg = base_cfg();
        cfg.quant.scheme = LevelScheme::Uniform; // no stat-exchange rounds
        cfg.algo.variant = Variant::DualExtrapolation;
        let rec_de = run_experiment(&cfg).unwrap();
        cfg.algo.variant = Variant::OptimisticDualAveraging;
        let rec_opt = run_experiment(&cfg).unwrap();
        let r_de = rec_de.scalar("rounds").unwrap();
        let r_opt = rec_opt.scalar("rounds").unwrap();
        assert!((r_de / r_opt - 2.0).abs() < 0.01, "de {r_de} opt {r_opt}");
    }

    #[test]
    fn more_workers_reduce_final_error_under_absolute_noise() {
        // Theorem 3's 1/sqrt(K): K=8 should beat K=1 on the same budget.
        // Average over seeds — a single run's final gap is itself noisy.
        let mut d1 = 0.0;
        let mut d8 = 0.0;
        for seed in 0..5u64 {
            let mut cfg = base_cfg();
            cfg.seed = 1000 + seed;
            cfg.iters = 1500;
            cfg.problem.sigma = 2.0;
            cfg.algo.gamma0 = 0.3;
            cfg.workers = 1;
            d1 += run_experiment(&cfg).unwrap().get("dist").unwrap().last().unwrap();
            cfg.workers = 8;
            d8 += run_experiment(&cfg).unwrap().get("dist").unwrap().last().unwrap();
        }
        assert!(d8 < d1 * 0.8, "K=8 dist {d8} should beat K=1 dist {d1}");
    }

    #[test]
    fn qsgda_baseline_runs() {
        let mut cfg = base_cfg();
        cfg.iters = 300;
        let rec = run_qsgda_baseline(&cfg).unwrap();
        assert!(rec.get("dist").unwrap().last().unwrap().is_finite());
    }

    #[test]
    fn exact_topologies_share_one_trajectory_but_not_one_cost() {
        // Star/ring/hierarchical aggregate the same rank-order mean the mesh
        // broadcasts, so the iterate trajectory is bit-identical; only the
        // modeled traffic and time differ.
        let mut cfg = base_cfg();
        cfg.workers = 8;
        cfg.iters = 120;
        cfg.eval_every = 40;
        let mesh = run_experiment(&cfg).unwrap();
        for kind in ["star", "ring", "hierarchical"] {
            cfg.topo.kind = kind.into();
            let rec = run_experiment(&cfg).unwrap();
            assert_eq!(
                rec.get("gap").unwrap().ys(),
                mesh.get("gap").unwrap().ys(),
                "{kind} trajectory must match full mesh bit-for-bit"
            );
            assert!(
                rec.scalar("total_bits").unwrap() < mesh.scalar("total_bits").unwrap(),
                "{kind} must aggregate below mesh traffic"
            );
            assert!(rec.scalar("max_link_bytes").unwrap() > 0.0);
        }
    }

    #[test]
    fn gossip_runs_and_tracks_consensus() {
        let mut cfg = base_cfg();
        cfg.workers = 8;
        cfg.iters = 200;
        cfg.eval_every = 50;
        cfg.topo.kind = "gossip".into();
        cfg.topo.degree = 3;
        let rec = run_experiment(&cfg).unwrap();
        let cons = rec.get("consensus_dist").unwrap();
        assert!(cons.points.iter().all(|(_, y)| y.is_finite()));
        assert!(rec.scalar("consensus_dist").unwrap().is_finite());
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
        // neighborhood exchange puts fewer bits on the wire than the mesh
        cfg.topo.kind = "full-mesh".into();
        let mesh = run_experiment(&cfg).unwrap();
        assert!(rec.scalar("total_bits").unwrap() < mesh.scalar("total_bits").unwrap());
        // replicas genuinely diverge under noise
        assert!(rec.scalar("consensus_dist").unwrap() > 0.0);
    }

    #[test]
    fn huffman_with_fixed_levels_actually_refreshes_mid_run() {
        // Regression for the silent Huffman-refresh no-op: with uniform
        // (fixed) levels and a Huffman codec, the scheduled stat rounds
        // used to exchange empty payloads — the pooled stats were empty,
        // update_levels bailed out early, and `level_updates` stayed 0
        // even though the run paid the stat-round network cost.
        let mut cfg = base_cfg();
        cfg.quant.scheme = LevelScheme::Uniform;
        cfg.quant.codec = crate::coding::SymbolCodec::Huffman;
        cfg.iters = 300;
        let rec = run_experiment(&cfg).unwrap();
        assert!(
            rec.scalar("level_updates").unwrap() >= 1.0,
            "fixed-levels Huffman run must perform at least one real codec refresh"
        );
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
    }

    #[test]
    fn local_steps_one_is_bit_identical_to_seed_exact_runner() {
        // `local.steps = 1` must not engage the delta-sync machinery: the
        // run is the seed per-step dual exchange, bit-for-bit, for every
        // variant.
        for v in [Variant::DualAveraging, Variant::DualExtrapolation, Variant::OptimisticDualAveraging]
        {
            let mut cfg = base_cfg();
            cfg.algo.variant = v;
            cfg.iters = 200;
            let seed_rec = run_experiment(&cfg).unwrap();
            cfg.local.steps = 1; // explicit, same meaning as the default
            let local_rec = run_experiment(&cfg).unwrap();
            assert_eq!(
                seed_rec.get("gap").unwrap().ys(),
                local_rec.get("gap").unwrap().ys(),
                "variant {v:?} trajectory must match the seed bit-for-bit"
            );
            assert_eq!(
                seed_rec.scalar("total_bits"),
                local_rec.scalar("total_bits"),
                "variant {v:?} wire bits must match the seed exactly"
            );
            assert!(local_rec.scalar("syncs").is_none(), "no delta-sync path at H = 1");
        }
    }

    #[test]
    fn local_steps_converge_and_cut_wire_bits() {
        let mut cfg = base_cfg();
        cfg.iters = 600;
        cfg.eval_every = 150;
        let exact = run_experiment(&cfg).unwrap();
        cfg.local.steps = 4;
        let local = run_experiment(&cfg).unwrap();

        // Still converges on the MonotoneQuadratic.
        let gaps = local.get("gap").unwrap();
        let first = gaps.points.first().unwrap().1;
        let last = gaps.last().unwrap();
        assert!(last < first, "local-steps gap should shrink: {first} -> {last}");
        assert!(last < 1.0, "local-steps final gap too large: {last}");

        // Communicating every 4th iteration strictly cuts total wire bits.
        let bits_local = local.scalar("total_bits").unwrap();
        let bits_exact = exact.scalar("total_bits").unwrap();
        assert!(
            bits_local < bits_exact,
            "H = 4 must send fewer bits: {bits_local} vs {bits_exact}"
        );

        // Sync accounting: 600 / 4 syncs, drift accumulates between syncs,
        // and the final sync leaves the replicas bit-identical.
        assert_eq!(local.scalar("syncs"), Some(150.0));
        assert_eq!(local.scalar("local_steps"), Some(4.0));
        assert!(local.scalar("bits_per_sync").unwrap() > 0.0);
        let drift = local.get("sync_drift").unwrap();
        assert!(drift.points.iter().all(|(_, y)| y.is_finite()));
        assert!(
            drift.ys().iter().any(|&y| y > 0.0),
            "private noisy oracles must produce nonzero intra-segment drift"
        );
        assert_eq!(
            local.scalar("consensus_dist"),
            Some(0.0),
            "exact topology: replicas must be bit-identical after the final sync"
        );
    }

    #[test]
    fn local_steps_refresh_codecs_even_on_short_runs() {
        // Regression: the local stat schedule must keep the per-step
        // runners' early warmup — a run shorter than update_every still
        // performs a real refresh at the first sync past the warmup point.
        let mut cfg = base_cfg();
        cfg.iters = 60; // < update_every (100)
        cfg.local.steps = 4;
        let rec = run_experiment(&cfg).unwrap();
        assert!(
            rec.scalar("level_updates").unwrap() >= 1.0,
            "short local runs must still refresh the codec"
        );
    }

    #[test]
    fn local_steps_compose_with_gossip() {
        let mut cfg = base_cfg();
        cfg.workers = 8;
        cfg.iters = 200;
        cfg.eval_every = 50;
        cfg.local.steps = 5;
        cfg.topo.kind = "gossip".into();
        cfg.topo.degree = 3;
        let rec = run_experiment(&cfg).unwrap();
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
        assert_eq!(rec.scalar("syncs"), Some(40.0));
        // neighborhood averaging never reaches full consensus
        assert!(rec.scalar("consensus_dist").unwrap() > 0.0);
    }

    #[test]
    fn single_layer_map_reproduces_all_three_runners_bit_for_bit() {
        // The Q-GenX-LW acceptance contract: a one-layer [quant.layers]
        // map runs the seed machinery — identical trajectories AND
        // identical wire accounting — for the exact, gossip, and local
        // runner families.
        for (kind, h) in [("full-mesh", 1usize), ("gossip", 1), ("full-mesh", 4)] {
            let mut cfg = base_cfg();
            cfg.workers = 8;
            cfg.iters = 160;
            cfg.eval_every = 40;
            cfg.topo.kind = kind.into();
            cfg.local.steps = h;
            let baseline = run_experiment(&cfg).unwrap();
            cfg.quant.layers.names = vec!["all".into()];
            let layered = run_experiment(&cfg).unwrap();
            assert_eq!(
                baseline.get("gap").unwrap().ys(),
                layered.get("gap").unwrap().ys(),
                "{kind}/H={h}: trajectory must match bit-for-bit"
            );
            assert_eq!(
                baseline.scalar("total_bits"),
                layered.scalar("total_bits"),
                "{kind}/H={h}: wire bits must match exactly"
            );
            assert!(
                layered.scalar("layers").is_none(),
                "one layer must not surface layer-wise metrics"
            );
        }
    }

    #[test]
    fn layerwise_runner_end_to_end_with_budget() {
        let mut cfg = base_cfg();
        cfg.problem.dim = 96;
        cfg.iters = 300;
        cfg.quant.bucket_size = 32;
        cfg.quant.scheme = LevelScheme::Uniform;
        cfg.quant.codec = crate::coding::SymbolCodec::Fixed;
        cfg.quant.layers.names = vec!["embed".into(), "body".into(), "head".into()];
        cfg.quant.layers.bounds = vec![32, 64];
        cfg.quant.layers.budget = 4.0;
        let rec = run_experiment(&cfg).unwrap();
        // Converges, refreshes (the budget forces stat rounds even though
        // scheme/codec are static), and surfaces per-layer accounting.
        let gaps = rec.get("gap").unwrap();
        assert!(gaps.last().unwrap() < gaps.points.first().unwrap().1);
        assert!(rec.scalar("level_updates").unwrap() >= 1.0);
        assert_eq!(rec.scalar("layers"), Some(3.0));
        let mut layer_sum = 0.0;
        for name in ["embed", "body", "head"] {
            let bits = rec.scalar(&format!("layer_bits/{name}")).unwrap();
            assert!(bits > 0.0, "{name} must put bits on the wire");
            layer_sum += bits;
            assert!(rec.scalar(&format!("layer_variance/{name}")).unwrap() > 0.0);
            assert!(rec.scalar(&format!("layer_levels/{name}")).unwrap() >= 1.0);
            let series = rec.get(&format!("layer_bits/{name}")).unwrap();
            assert!(series.len() >= 2 && series.last().unwrap() > 0.0);
        }
        // Per-layer payload bits are one worker's share (before collective
        // amplification and framing), so they undercount the global total.
        assert!(layer_sum < rec.scalar("total_bits").unwrap());
        // epsilon_q scalar is the dimension-weighted blend — finite, > 0.
        let eps = rec.scalar("epsilon_q").unwrap();
        assert!(eps.is_finite() && eps > 0.0);
    }

    #[test]
    fn layerwise_composes_with_gossip_and_local_steps() {
        let mut cfg = base_cfg();
        cfg.workers = 8;
        cfg.problem.dim = 48;
        cfg.iters = 200;
        cfg.eval_every = 50;
        cfg.quant.bucket_size = 16;
        cfg.quant.layers.names = vec!["lo".into(), "hi".into()];
        cfg.quant.layers.bounds = vec![16];
        cfg.topo.kind = "gossip".into();
        cfg.topo.degree = 3;
        let rec = run_experiment(&cfg).unwrap();
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
        assert_eq!(rec.scalar("layers"), Some(2.0));
        assert!(rec.scalar("consensus_dist").unwrap() > 0.0);

        cfg.topo.kind = "full-mesh".into();
        cfg.local.steps = 4;
        let rec = run_experiment(&cfg).unwrap();
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
        assert_eq!(rec.scalar("layers"), Some(2.0));
        assert_eq!(rec.scalar("syncs"), Some(50.0));
        assert_eq!(
            rec.scalar("consensus_dist"),
            Some(0.0),
            "exact topology: layer-wise replicas must re-sync exactly"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = base_cfg();
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(
            a.get("gap").unwrap().ys(),
            b.get("gap").unwrap().ys(),
            "inline runner must be deterministic"
        );
    }
}
