//! Baselines for the paper's comparisons.
//!
//! * [`ExtraGradient`] — Korpelevich (1976) with a fixed step `γ ≤ 1/L`:
//!   the full-precision, non-adaptive reference.
//! * [`Sgda`] — stochastic gradient descent-ascent with `γ_t = γ₀/√t`.
//!   With quantized inputs this *is* QSGDA (Beznosikov et al. 2022, the
//!   no-variance-reduction method of Figure 4): the caller feeds it
//!   quantized averaged dual vectors exactly as it feeds Q-GenX.
//!
//! Both expose the same feed-the-vectors protocol as
//! [`crate::algo::QGenX`] so the coordinator and benches can swap
//! algorithms without touching the communication code.

use crate::util::{axpy, mean_into};

/// Fixed-step extra-gradient (two oracle queries per iteration).
#[derive(Clone)]
pub struct ExtraGradient {
    x: Vec<f32>,
    x_half: Vec<f32>,
    x_half_sum: Vec<f64>,
    gamma: f64,
    t: usize,
    mean_buf: Vec<f32>,
}

impl ExtraGradient {
    pub fn new(x0: &[f32], gamma: f64) -> Self {
        let d = x0.len();
        ExtraGradient {
            x: x0.to_vec(),
            x_half: vec![0.0; d],
            x_half_sum: vec![0.0; d],
            gamma,
            t: 0,
            mean_buf: vec![0.0; d],
        }
    }

    pub fn x(&self) -> &[f32] {
        &self.x
    }

    pub fn iteration(&self) -> usize {
        self.t
    }

    /// Query point for the first leg.
    pub fn base_query(&self) -> Vec<f32> {
        self.x.clone()
    }

    /// First leg: `X_{t+1/2} = X_t − γ ḡ(X_t)`; returns the half point.
    pub fn extrapolate(&mut self, base_vectors: &[Vec<f32>]) -> Vec<f32> {
        let refs: Vec<&[f32]> = base_vectors.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut self.mean_buf);
        self.x_half.copy_from_slice(&self.x);
        axpy(-(self.gamma as f32), &self.mean_buf, &mut self.x_half);
        self.x_half.clone()
    }

    /// Second leg: `X_{t+1} = X_t − γ ḡ(X_{t+1/2})`.
    pub fn update(&mut self, half_vectors: &[Vec<f32>]) {
        for i in 0..self.x.len() {
            self.x_half_sum[i] += self.x_half[i] as f64;
        }
        let refs: Vec<&[f32]> = half_vectors.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut self.mean_buf);
        axpy(-(self.gamma as f32), &self.mean_buf, &mut self.x);
        self.t += 1;
    }

    pub fn ergodic_average(&self) -> Vec<f32> {
        let t = self.t.max(1) as f64;
        self.x_half_sum.iter().map(|&s| (s / t) as f32).collect()
    }
}

/// (Q)SGDA: `X_{t+1} = X_t − γ_t ḡ(X_t)`, `γ_t = γ₀ / √t`.
#[derive(Clone)]
pub struct Sgda {
    x: Vec<f32>,
    x_sum: Vec<f64>,
    gamma0: f64,
    t: usize,
    mean_buf: Vec<f32>,
    /// `γ_t = γ₀/√t` when true, else constant γ₀.
    decay: bool,
}

impl Sgda {
    pub fn new(x0: &[f32], gamma0: f64, decay: bool) -> Self {
        let d = x0.len();
        Sgda { x: x0.to_vec(), x_sum: vec![0.0; d], gamma0, t: 0, mean_buf: vec![0.0; d], decay }
    }

    pub fn x(&self) -> &[f32] {
        &self.x
    }

    pub fn gamma(&self) -> f64 {
        if self.decay {
            self.gamma0 / ((self.t + 1) as f64).sqrt()
        } else {
            self.gamma0
        }
    }

    pub fn query(&self) -> Vec<f32> {
        self.x.clone()
    }

    /// One step from the `K` (possibly quantized) dual vectors at `X_t`.
    pub fn update(&mut self, vectors: &[Vec<f32>]) {
        for i in 0..self.x.len() {
            self.x_sum[i] += self.x[i] as f64;
        }
        let g = self.gamma() as f32;
        let refs: Vec<&[f32]> = vectors.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut self.mean_buf);
        axpy(-g, &self.mean_buf, &mut self.x);
        self.t += 1;
    }

    pub fn ergodic_average(&self) -> Vec<f32> {
        let t = self.t.max(1) as f64;
        self.x_sum.iter().map(|&s| (s / t) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{ExactOracle, MonotoneQuadratic, Operator, Oracle, RotationOperator};
    use crate::util::{dist_sq, Rng};
    use std::sync::Arc;

    #[test]
    fn eg_converges_on_quadratic() {
        let mut rng = Rng::seed_from(1);
        let op = Arc::new(MonotoneQuadratic::random(10, 0.3, 1.0, &mut rng).unwrap());
        let xs = op.solution().unwrap();
        let l = op.lipschitz().unwrap();
        let mut oracle = ExactOracle::new(op.clone());
        let x0 = vec![0.0f32; 10];
        let mut eg = ExtraGradient::new(&x0, 0.5 / l);
        for _ in 0..2000 {
            let xq = eg.base_query();
            let mut g = vec![0.0f32; 10];
            oracle.sample(&xq, &mut g);
            let xh = eg.extrapolate(&[g]);
            let mut gh = vec![0.0f32; 10];
            oracle.sample(&xh, &mut gh);
            eg.update(&[gh]);
        }
        let r = dist_sq(eg.x(), &xs) / dist_sq(&x0, &xs);
        assert!(r < 1e-4, "ratio {r}");
    }

    #[test]
    fn sgda_converges_on_strongly_monotone_but_not_rotation() {
        let mut rng = Rng::seed_from(2);
        let op = Arc::new(MonotoneQuadratic::random(10, 0.5, 1.0, &mut rng).unwrap());
        let xs = op.solution().unwrap();
        let mut oracle = ExactOracle::new(op.clone());
        let x0 = vec![0.0f32; 10];
        let mut sgda = Sgda::new(&x0, 0.3, true);
        for _ in 0..4000 {
            let xq = sgda.query();
            let mut g = vec![0.0f32; 10];
            oracle.sample(&xq, &mut g);
            sgda.update(&[g]);
        }
        let r = dist_sq(sgda.x(), &xs) / dist_sq(&x0, &xs);
        assert!(r < 1e-2, "quadratic ratio {r}");

        // On pure rotation SGDA with decaying steps drifts, EG-style wins.
        let rot = Arc::new(RotationOperator::new(8, 0.0, 1.0).unwrap());
        let rs = rot.solution().unwrap();
        let mut o2 = ExactOracle::new(rot.clone());
        let z0 = vec![0.0f32; 8];
        let mut sg = Sgda::new(&z0, 0.3, true);
        for _ in 0..4000 {
            let xq = sg.query();
            let mut g = vec![0.0f32; 8];
            o2.sample(&xq, &mut g);
            sg.update(&[g]);
        }
        let r_sgda = dist_sq(sg.x(), &rs) / dist_sq(&z0, &rs);
        // SGDA does not contract on rotation (last iterate no better than start).
        assert!(r_sgda > 0.5, "sgda rotation ratio {r_sgda}");
    }

    #[test]
    fn sgda_gamma_decays() {
        let mut s = Sgda::new(&[0.0; 2], 1.0, true);
        let g1 = s.gamma();
        s.update(&[vec![0.0; 2]]);
        s.update(&[vec![0.0; 2]]);
        s.update(&[vec![0.0; 2]]);
        let g4 = s.gamma();
        assert!((g1 / g4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ergodic_averages_track_iterates() {
        let mut eg = ExtraGradient::new(&[1.0, 1.0], 0.1);
        let z = vec![vec![0.0f32; 2]];
        for _ in 0..3 {
            let _ = eg.extrapolate(&z);
            eg.update(&z);
        }
        assert_eq!(eg.ergodic_average(), vec![1.0, 1.0]);
    }
}
