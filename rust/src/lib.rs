//! # qgenx — Distributed Extra-gradient with Optimal Complexity and Communication Guarantees
//!
//! A production-style reproduction of **Q-GenX** (Ramezani-Kebrya et al.,
//! ICLR 2023): a family of quantized, communication-efficient generalized
//! extra-gradient methods for monotone variational inequalities (VIs) on
//! `K` synchronous processors.
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — a Pallas stochastic-quantization kernel (build-time Python,
//!   `python/compile/kernels/`), lowered together with
//! * **L2** — JAX compute graphs (tiny-GPT LM and a WGAN-GP-style GAN,
//!   `python/compile/model.py`) into AOT HLO-text artifacts, which
//! * **L3** — this crate loads through PJRT ([`runtime`]) and drives from a
//!   distributed coordinator ([`coordinator`]) that quantizes ([`quant`]),
//!   entropy-codes ([`coding`]) and exchanges ([`net`]) stochastic dual
//!   vectors between workers, exactly as Algorithm 1 of the paper.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.
//!
//! ## Module map
//!
//! | module | role |
//! |--------|------|
//! | [`util`] | PRNG (xoshiro256++), vector math, running statistics |
//! | [`testkit`] | in-house property-testing harness (no `proptest` offline) |
//! | [`config`] | TOML-subset parser + typed experiment configuration |
//! | [`coding`] | bit-level IO, Elias γ/δ/ω codes, canonical Huffman |
//! | [`quant`] | `Q_ℓ` random quantization (Def. 1), wire format (`CODE∘Q`), QAda adaptive levels, layer-wise partition + Theorem-1 bit-budget allocator (Q-GenX-LW), Thm-1/Thm-2 bound calculators |
//! | [`oracle`] | monotone VI problem suite, absolute/relative noise oracles, restricted gap function |
//! | [`algo`] | Q-GenX template (DA/DE/OptDA) with adaptive step-size, local-steps replica wrapper, baselines (EG, SGDA, QSGDA) |
//! | [`net`] | transport fabrics: α-β cost model, in-process `AllGather` barrier, socket transport (length-framed TCP / Unix-domain mesh), measured-byte accounting |
//! | [`topo`] | topology-aware collectives: full-mesh / star / ring / hierarchical / gossip exchange graphs, per-topology α-β cost, per-link traffic |
//! | [`coordinator`] | the steppable `Session` run API over the shared round engine (Algorithm 1); exact / gossip / local exchange policies + SGDA baseline; one-shot wrappers |
//! | [`runtime`] | PJRT client: load + execute AOT HLO artifacts |
//! | [`train`] | GAN / LM training drivers over the runtime |
//! | [`metrics`] | time-series recorder, CSV emission |
//! | [`telemetry`] | run telemetry: stage spans, counters, per-link streams, ring + JSONL sinks |
//! | [`benchkit`] | bench harness (no `criterion` offline), counting allocator |
//!
//! User-facing references: `rust/README.md` (crate tour, scenario
//! families, bench ↔ theorem map), `docs/API.md` (the Session run API:
//! lifecycle, Observer contract, checkpoint/resume, migration table),
//! `docs/CONFIG.md` (every TOML table and CLI flag), `docs/WIRE.md`
//! (payload and stat wire formats + the socket frame envelope),
//! `docs/OBSERVABILITY.md` (telemetry event schema, span taxonomy,
//! sinks, overhead contract).

pub mod algo;
pub mod benchkit;
pub mod coding;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod metrics;
pub mod net;
pub mod oracle;
pub mod quant;
pub mod runtime;
pub mod telemetry;
pub mod testkit;
pub mod topo;
pub mod train;
pub mod util;

pub use error::{Error, Result};
