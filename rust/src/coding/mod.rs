//! Bit-level IO and entropy coding — the `CODE` half of the paper's
//! `CODE ∘ Q` pipeline (§3.2, Appendix K).
//!
//! A quantized dual vector is a tuple `(‖v‖_q, s, q_ℓ(u))`; the norm is sent
//! as a 32-bit float (`C_b = 32`), each nonzero coordinate's sign as one
//! bit, and the level *indices* through a lossless prefix code Ψ:
//!
//! * [`elias`] — Elias γ/δ/ω universal codes for the "distribution unknown,
//!   small symbols more frequent" regime (the QSGD-style baseline);
//! * [`huffman`] — canonical Huffman built from the QAda symbol
//!   probabilities of Proposition 2 — the minimum-expected-length prefix
//!   code when the distribution is known (Cover & Thomas Thm 5.4.1/5.8.1).
//!
//! [`bitio`] provides the LSB-first bit writer/reader both codecs share.

pub mod bitio;
pub mod elias;
pub mod huffman;

pub use bitio::{BitReader, BitWriter};
pub use huffman::HuffmanCode;

/// Which prefix code Ψ encodes quantization-level indices on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SymbolCodec {
    /// Elias gamma on (index+1): universal, no side information.
    EliasGamma,
    /// Elias delta on (index+1): better for larger alphabets.
    EliasDelta,
    /// Canonical Huffman from estimated symbol probabilities; code lengths
    /// are shipped once per level-update (schedule `U`), not per message.
    Huffman,
    /// Fixed-width ceil(log2(s+2)) bits per symbol (the no-entropy-coding
    /// ablation; equivalent to what torch_cgx's UQ4/UQ8 put on the wire).
    Fixed,
}

impl SymbolCodec {
    pub fn name(&self) -> &'static str {
        match self {
            SymbolCodec::EliasGamma => "elias-gamma",
            SymbolCodec::EliasDelta => "elias-delta",
            SymbolCodec::Huffman => "huffman",
            SymbolCodec::Fixed => "fixed",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "elias-gamma" | "gamma" => Some(SymbolCodec::EliasGamma),
            "elias-delta" | "delta" => Some(SymbolCodec::EliasDelta),
            "huffman" => Some(SymbolCodec::Huffman),
            "fixed" => Some(SymbolCodec::Fixed),
            _ => None,
        }
    }
}
