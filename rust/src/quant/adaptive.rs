//! QAda — adaptive quantization levels (paper §3.3).
//!
//! Instead of heuristic level placement, QAda (i) estimates the
//! distribution of *normalized* dual-vector coordinates through a cheap
//! sufficient statistic, (ii) minimizes the quantization variance
//!
//! `min_ℓ Σ_i ∫_{ℓ_i}^{ℓ_{i+1}} σ_Q²(u; ℓ) dF̃(u)`   (QAda)
//!
//! over the weighted CDF `F̃ = Σ_j λ_j F_j`, `λ_j ∝ ‖g_j‖_q²`, and (iii)
//! re-solves on the update schedule `U` as the gradient distribution
//! drifts during training.
//!
//! The optimizer is the "update levels one at a time" scheme of Faghri et
//! al. (2020): coordinate descent where each inner step solves the scalar
//! first-order condition
//!
//! `Σ_{u ∈ (ℓ_{j-1}, ℓ_j)} (u − ℓ_{j-1}) dF̃ = Σ_{u ∈ (ℓ_j, ℓ_{j+1})} (ℓ_{j+1} − u) dF̃`
//!
//! by bisection (the residual is monotone in ℓ_j). Each sweep never
//! increases the objective, so the iteration converges to a stationary
//! point of (QAda).

use super::levels::Levels;
use crate::error::{Error, Result};
use crate::util::{norm_q, Histogram};

/// Sufficient statistics for QAda: a weighted histogram of normalized
/// coordinate magnitudes, weights `λ_j ∝ ‖g_j‖_q²` (law-of-total-expectation
/// weighting from the paper's QAda derivation).
#[derive(Clone, Debug)]
pub struct SufficientStats {
    hist: Histogram,
    q: u32,
    vectors_seen: usize,
    /// Raw norm² mass `Σ_j ‖g_j‖_q²` of the observed vectors (buckets). The
    /// histogram only keeps the normalized *shape*; the layer-wise
    /// bit-budget allocator additionally needs this absolute Theorem-1
    /// weight per layer, so it travels in the v3 stat block
    /// ([`Self::to_block_v3`]) — the v2 payload predates it.
    weight_sum: f64,
}

impl SufficientStats {
    pub fn new(bins: usize, q: u32) -> Self {
        SufficientStats { hist: Histogram::new(bins), q, vectors_seen: 0, weight_sum: 0.0 }
    }

    /// Accumulate one sampled dual vector `g` (one of the J samples).
    pub fn observe(&mut self, g: &[f32]) {
        let norm = norm_q(g, self.q);
        if norm == 0.0 {
            return;
        }
        // λ_j ∝ ‖g_j‖_q²; the histogram normalizes by total mass so the
        // proportionality constant cancels.
        self.hist.push_normalized(g, norm, norm * norm);
        self.vectors_seen += 1;
        self.weight_sum += norm * norm;
    }

    /// Accumulate bucketed: one weight per bucket (matches the bucketed
    /// quantizer, where each bucket is normalized independently).
    pub fn observe_bucketed(&mut self, g: &[f32], bucket_size: usize) {
        let b = if bucket_size == 0 { g.len() } else { bucket_size };
        for chunk in g.chunks(b) {
            self.observe(chunk);
        }
    }

    /// Merge stats pooled from another worker (leader-side aggregation).
    pub fn merge(&mut self, other: &SufficientStats) {
        assert_eq!(self.q, other.q);
        self.hist.merge(&other.hist);
        self.vectors_seen += other.vectors_seen;
        self.weight_sum += other.weight_sum;
    }

    pub fn vectors_seen(&self) -> usize {
        self.vectors_seen
    }

    /// Accumulated norm² mass `Σ_j ‖g_j‖_q²` — the Theorem-1 weight of this
    /// segment's observations (what `λ_j ∝ ‖g_j‖_q²` sums to before
    /// normalization). Carried by the v3 stat block only; pooling v2
    /// payloads ([`Self::absorb_bytes`]) leaves it untouched.
    pub fn total_weight(&self) -> f64 {
        self.weight_sum
    }

    pub fn is_empty(&self) -> bool {
        self.hist.total() == 0.0
    }

    /// `F̃(u)` — the weighted CDF.
    pub fn cdf(&self, u: f64) -> f64 {
        self.hist.cdf(u)
    }

    /// Serialize the sufficient statistic for the inter-worker stat
    /// exchange at level-update steps (wire format v2): a `u32` LE
    /// vector count followed by the bin masses as f32 LE. The whole point
    /// of sufficient statistics is that this is tiny: `4 + 4 × hist_bins`
    /// bytes regardless of `d`.
    ///
    /// The count travels with the masses so that pooling from payloads
    /// ([`Self::absorb_bytes`]) agrees with in-memory pooling
    /// ([`Self::merge`]) — v1 omitted it and counted one vector per
    /// absorbed *payload*, silently under-reporting pooled sample sizes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + 4 * self.hist.bins());
        out.extend_from_slice(&(self.vectors_seen.min(u32::MAX as usize) as u32).to_le_bytes());
        for &c in self.hist.bin_counts() {
            out.extend_from_slice(&(c as f32).to_le_bytes());
        }
        out
    }

    /// Pool a peer's serialized statistic into this one.
    pub fn absorb_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        if bytes.len() != 4 + 4 * self.hist.bins() {
            return Err(Error::Quant(format!(
                "stat payload {} bytes, expected {} (u32 count + {} bins)",
                bytes.len(),
                4 + 4 * self.hist.bins(),
                self.hist.bins()
            )));
        }
        let (head, body) = bytes.split_at(4);
        let peer_vectors = u32::from_le_bytes([head[0], head[1], head[2], head[3]]) as usize;
        let counts: Vec<f64> = body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64)
            .collect();
        self.hist.add_counts(&counts);
        self.vectors_seen += peer_vectors;
        Ok(())
    }

    /// One per-layer block of the v3 stat payload:
    /// `[u32 vectors_seen][f32 norm² mass][bins × f32 bin mass]` (all LE) —
    /// the v2 payload of [`Self::to_bytes`] with the Theorem-1 weight
    /// spliced in after the count. `8 + 4 × hist_bins` bytes. The framing
    /// (layer-count header) lives in [`crate::quant::layers::LayerStats`].
    pub fn to_block_v3(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 4 * self.hist.bins());
        out.extend_from_slice(&(self.vectors_seen.min(u32::MAX as usize) as u32).to_le_bytes());
        out.extend_from_slice(&(self.weight_sum as f32).to_le_bytes());
        for &c in self.hist.bin_counts() {
            out.extend_from_slice(&(c as f32).to_le_bytes());
        }
        out
    }

    /// Pool one serialized v3 block ([`Self::to_block_v3`]) into this stat.
    pub fn absorb_block_v3(&mut self, bytes: &[u8]) -> Result<()> {
        if bytes.len() != 8 + 4 * self.hist.bins() {
            return Err(Error::Quant(format!(
                "v3 stat block {} bytes, expected {} (count + weight + {} bins)",
                bytes.len(),
                8 + 4 * self.hist.bins(),
                self.hist.bins()
            )));
        }
        let weight = f32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as f64;
        if !weight.is_finite() || weight < 0.0 {
            return Err(Error::Quant(format!("bad v3 stat weight {weight}")));
        }
        // Count + masses are laid out exactly as v2 once the weight is cut
        // out; reuse the v2 parser for them.
        let mut v2 = Vec::with_capacity(4 + 4 * self.hist.bins());
        v2.extend_from_slice(&bytes[..4]);
        v2.extend_from_slice(&bytes[8..]);
        self.absorb_bytes(&v2)?;
        self.weight_sum += weight;
        Ok(())
    }

    /// Reset to empty (start of a new schedule segment T_j).
    pub fn reset(&mut self) {
        self.hist = Histogram::new(self.hist.bins());
        self.vectors_seen = 0;
        self.weight_sum = 0.0;
    }

    /// Probability mass in `[a, b)` under `F̃`.
    fn mass(&self, a: f64, b: f64) -> f64 {
        (self.cdf(b) - self.cdf(a)).max(0.0)
    }

    /// First moment `∫_a^b u dF̃(u)`, approximated from histogram bins
    /// (mass at bin centers).
    fn first_moment(&self, a: f64, b: f64) -> f64 {
        let nb = self.hist.bins();
        let mut acc = 0.0;
        for m in 0..nb {
            let lo = m as f64 / nb as f64;
            let hi = (m + 1) as f64 / nb as f64;
            let center = 0.5 * (lo + hi);
            // overlap fraction of bin [lo,hi) with [a,b)
            let olo = lo.max(a);
            let ohi = hi.min(b);
            if ohi > olo {
                let frac = (ohi - olo) / (hi - lo);
                acc += self.hist.pmf(m) * frac * center;
            }
        }
        acc
    }

    /// The QAda objective: expected per-coordinate quantization variance
    /// `Σ_bins ∫ σ_Q²(u; ℓ) dF̃(u)` (up to the common `‖v‖²` factor).
    pub fn objective(&self, levels: &Levels) -> f64 {
        let nb = self.hist.bins();
        let mut acc = 0.0;
        for m in 0..nb {
            let center = (m as f64 + 0.5) / nb as f64;
            acc += self.hist.pmf(m) * levels.coord_variance(center);
        }
        acc
    }
}

/// Proposition 2: symbol occurrence probabilities `p_0..p_{s+1}` under `F̃`
/// and the stochastic rounding rule:
///
/// `p_j = ∫_{ℓ_{j-1}}^{ℓ_j} (u−ℓ_{j-1})/(ℓ_j−ℓ_{j-1}) dF̃
///      + ∫_{ℓ_j}^{ℓ_{j+1}} (ℓ_{j+1}−u)/(ℓ_{j+1}−ℓ_j) dF̃`.
pub fn symbol_probs(stats: &SufficientStats, levels: &Levels) -> Vec<f64> {
    let s = levels.s();
    let mut probs = vec![0.0f64; s + 2];
    for j in 0..=(s + 1) {
        let lj = levels.value(j);
        let mut p = 0.0;
        if j > 0 {
            // rounded *up* to ℓ_j from the bin below
            let lo = levels.value(j - 1);
            let w = lj - lo;
            if w > 0.0 {
                let m1 = stats.first_moment(lo, lj);
                let m0 = stats.mass(lo, lj);
                p += (m1 - lo * m0) / w;
            }
        }
        if j <= s {
            // rounded *down* to ℓ_j from the bin above
            let hi = levels.value(j + 1);
            let w = hi - lj;
            if w > 0.0 {
                let m1 = stats.first_moment(lj, hi);
                let m0 = stats.mass(lj, hi);
                p += (hi * m0 - m1) / w;
            }
        }
        probs[j] = p.max(0.0);
    }
    // Account for mass exactly at 1.0 (CDF convention: mass(ℓ_s, 1) misses
    // the closed endpoint). Normalize to sum 1.
    let total: f64 = probs.iter().sum();
    if total > 0.0 {
        // Residual mass (e.g. u == 1.0 atoms) goes to the top symbol.
        let residual = (1.0 - total).max(0.0);
        probs[s + 1] += residual;
        let total: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= total;
        }
    }
    probs
}

/// Solve (QAda) by coordinate-descent sweeps with per-level bisection.
///
/// `s` = number of interior levels; `init` seeds the search (uniform if
/// `None`); `sweeps` full passes (8 is plenty — the objective is smooth and
/// each scalar solve is exact to bisection tolerance).
pub fn optimize_levels(
    stats: &SufficientStats,
    s: usize,
    init: Option<&Levels>,
    sweeps: usize,
) -> Result<Levels> {
    if stats.is_empty() {
        return Err(Error::Quant("QAda: no sufficient statistics observed".into()));
    }
    let mut cur: Vec<f64> = match init {
        Some(l) if l.s() == s => l.interior().to_vec(),
        _ => Levels::uniform(s).interior().to_vec(),
    };
    let eps = 1e-9;
    for _ in 0..sweeps {
        for j in 0..s {
            let lo_bound = if j == 0 { 0.0 } else { cur[j - 1] };
            let hi_bound = if j + 1 == s { 1.0 } else { cur[j + 1] };
            if hi_bound - lo_bound < 4.0 * eps {
                continue;
            }
            // residual(l) = ∫_{lo}^{l} (u - lo) dF - ∫_{l}^{hi} (hi' - u) dF
            // increasing in l; root = optimal ℓ_j given neighbors.
            let residual = |l: f64| -> f64 {
                let left = stats.first_moment(lo_bound, l) - lo_bound * stats.mass(lo_bound, l);
                let right = hi_bound * stats.mass(l, hi_bound) - stats.first_moment(l, hi_bound);
                left - right
            };
            let mut a = lo_bound + eps;
            let mut b = hi_bound - eps;
            let (ra, rb) = (residual(a), residual(b));
            if ra >= 0.0 {
                cur[j] = a;
                continue;
            }
            if rb <= 0.0 {
                cur[j] = b;
                continue;
            }
            for _ in 0..40 {
                let mid = 0.5 * (a + b);
                if residual(mid) < 0.0 {
                    a = mid;
                } else {
                    b = mid;
                }
            }
            cur[j] = 0.5 * (a + b);
        }
    }
    // Enforce strict monotonicity against numerical ties.
    for j in 1..s {
        if cur[j] <= cur[j - 1] {
            cur[j] = (cur[j - 1] + 1e-7).min(1.0 - 1e-7 * (s - j) as f64);
        }
    }
    Levels::new(cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close;
    use crate::util::Rng;

    fn gaussian_stats(bins: usize, d: usize, vecs: usize, seed: u64) -> SufficientStats {
        let mut stats = SufficientStats::new(bins, 2);
        let mut rng = Rng::seed_from(seed);
        for _ in 0..vecs {
            let g = rng.gaussian_vec(d, 1.0);
            stats.observe(&g);
        }
        stats
    }

    #[test]
    fn observe_accumulates() {
        let stats = gaussian_stats(128, 256, 8, 1);
        assert_eq!(stats.vectors_seen(), 8);
        assert!(!stats.is_empty());
        assert!(stats.cdf(1.0) > 0.99);
    }

    #[test]
    fn gaussian_coordinates_concentrate_near_zero() {
        // |N(0,1)| / ||g||_2 with d=1024 concentrates around 1/sqrt(d) ≈ 0.03.
        let stats = gaussian_stats(512, 1024, 16, 2);
        assert!(stats.cdf(0.1) > 0.95, "cdf(0.1)={}", stats.cdf(0.1));
        assert!(stats.cdf(0.01) < 0.6);
    }

    #[test]
    fn optimized_levels_beat_uniform_on_skewed_data() {
        let stats = gaussian_stats(512, 4096, 16, 3);
        let s = 15;
        let uniform = Levels::uniform(s);
        let adapted = optimize_levels(&stats, s, None, 8).unwrap();
        let obj_u = stats.objective(&uniform);
        let obj_a = stats.objective(&adapted);
        assert!(
            obj_a < obj_u * 0.5,
            "adaptive {obj_a} should be much below uniform {obj_u}"
        );
        // Adapted levels should crowd near zero where the mass is.
        assert!(adapted.l1() < uniform.l1());
    }

    #[test]
    fn optimize_is_monotone_in_objective() {
        let stats = gaussian_stats(256, 512, 8, 4);
        let s = 7;
        let l1 = optimize_levels(&stats, s, None, 1).unwrap();
        let l8 = optimize_levels(&stats, s, None, 8).unwrap();
        assert!(stats.objective(&l8) <= stats.objective(&l1) + 1e-12);
    }

    #[test]
    fn symbol_probs_sum_to_one_and_match_empirical() {
        let stats = gaussian_stats(512, 2048, 32, 5);
        let levels = optimize_levels(&stats, 7, None, 8).unwrap();
        let probs = symbol_probs(&stats, &levels);
        assert_eq!(probs.len(), 9);
        let total: f64 = probs.iter().sum();
        assert_close(total, 1.0, 1e-9);
        assert!(probs.iter().all(|&p| p >= 0.0));

        // Empirical check: quantize fresh vectors and compare frequencies.
        let mut rng = Rng::seed_from(77);
        let mut counts = vec![0usize; probs.len()];
        let mut n = 0usize;
        for _ in 0..64 {
            let g = rng.gaussian_vec(2048, 1.0);
            let qv = super::super::quantizer::quantize(&g, &levels, 2, 0, &mut rng).unwrap();
            for &sym in &qv.symbols {
                counts[sym as usize] += 1;
                n += 1;
            }
        }
        for (j, &p) in probs.iter().enumerate() {
            let emp = counts[j] as f64 / n as f64;
            assert!(
                (emp - p).abs() < 0.03 + 0.25 * p,
                "symbol {j}: empirical {emp} vs predicted {p}"
            );
        }
    }

    #[test]
    fn merge_pools_worker_stats() {
        let a = gaussian_stats(128, 256, 4, 6);
        let b = gaussian_stats(128, 256, 4, 7);
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.vectors_seen(), 8);
        // CDF of merge lies between the two.
        let u = 0.05;
        let lo = a.cdf(u).min(b.cdf(u));
        let hi = a.cdf(u).max(b.cdf(u));
        let m = merged.cdf(u);
        assert!(m >= lo - 1e-12 && m <= hi + 1e-12);
    }

    #[test]
    fn absorb_bytes_matches_merge_exactly() {
        // Wire-format v2 parity: pooling from serialized payloads must
        // agree with in-memory `merge` on both the histogram (up to f32
        // rounding of the masses) and — the v1 bug — the pooled vector
        // count.
        let a = gaussian_stats(128, 256, 4, 20);
        let b = gaussian_stats(128, 256, 7, 21);
        let mut merged = a.clone();
        merged.merge(&b);
        let mut absorbed = SufficientStats::new(128, 2);
        absorbed.absorb_bytes(&a.to_bytes()).unwrap();
        absorbed.absorb_bytes(&b.to_bytes()).unwrap();
        assert_eq!(absorbed.vectors_seen(), merged.vectors_seen());
        assert_eq!(absorbed.vectors_seen(), 11);
        for u in [0.01, 0.05, 0.2, 0.8] {
            assert!(
                (absorbed.cdf(u) - merged.cdf(u)).abs() < 1e-6,
                "cdf({u}) diverged: {} vs {}",
                absorbed.cdf(u),
                merged.cdf(u)
            );
        }
        // Truncated / oversized payloads are rejected, not misread.
        let bytes = a.to_bytes();
        assert_eq!(bytes.len(), 4 + 4 * 128);
        assert!(absorbed.absorb_bytes(&bytes[..bytes.len() - 4]).is_err());
        assert!(absorbed.absorb_bytes(&[0u8; 4]).is_err());
    }

    #[test]
    fn v3_block_carries_weight_v2_does_not() {
        let a = gaussian_stats(64, 256, 5, 40);
        assert!(a.total_weight() > 0.0);
        // v3 block round-trips count, masses AND weight.
        let mut s3 = SufficientStats::new(64, 2);
        s3.absorb_block_v3(&a.to_block_v3()).unwrap();
        assert_eq!(s3.vectors_seen(), a.vectors_seen());
        assert!((s3.total_weight() - a.total_weight()).abs() < 1e-4 * a.total_weight());
        // v2 payload (back-compat, single-layer pipelines) has no weight.
        let mut s2 = SufficientStats::new(64, 2);
        s2.absorb_bytes(&a.to_bytes()).unwrap();
        assert_eq!(s2.vectors_seen(), a.vectors_seen());
        assert_eq!(s2.total_weight(), 0.0);
        // Sizes: block = v2 + 4.
        assert_eq!(a.to_block_v3().len(), a.to_bytes().len() + 4);
        // Malformed blocks rejected.
        assert!(s3.absorb_block_v3(&a.to_bytes()).is_err());
        let mut bad = a.to_block_v3();
        bad[4..8].copy_from_slice(&f32::NEG_INFINITY.to_le_bytes());
        assert!(s3.absorb_block_v3(&bad).is_err());
    }

    #[test]
    fn empty_stats_rejected() {
        let stats = SufficientStats::new(64, 2);
        assert!(optimize_levels(&stats, 3, None, 4).is_err());
    }

    #[test]
    fn bucketed_observation() {
        let mut stats = SufficientStats::new(64, 2);
        let mut rng = Rng::seed_from(8);
        let g = rng.gaussian_vec(1000, 1.0);
        stats.observe_bucketed(&g, 100);
        // 10 buckets observed as 10 "vectors".
        assert_eq!(stats.vectors_seen(), 10);
    }

    #[test]
    fn adaptive_levels_reduce_true_quantization_error() {
        // End-to-end: measured E||Q(v)-v||^2 drops vs uniform levels.
        use super::super::quantizer::{dequantize, quantize};
        use crate::util::dist_sq;
        let stats = gaussian_stats(512, 4096, 8, 9);
        let s = 7;
        let uniform = Levels::uniform(s);
        let adapted = optimize_levels(&stats, s, None, 8).unwrap();
        let mut rng = Rng::seed_from(10);
        let mut err_u = 0.0;
        let mut err_a = 0.0;
        for _ in 0..30 {
            let v = rng.gaussian_vec(4096, 1.0);
            let qu = quantize(&v, &uniform, 2, 0, &mut rng).unwrap();
            let qa = quantize(&v, &adapted, 2, 0, &mut rng).unwrap();
            err_u += dist_sq(&v, &dequantize(&qu, &uniform));
            err_a += dist_sq(&v, &dequantize(&qa, &adapted));
        }
        assert!(err_a < 0.5 * err_u, "adaptive {err_a} vs uniform {err_u}");
    }
}
