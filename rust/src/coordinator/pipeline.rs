//! Per-worker compression pipeline: `Q_ℓ` → `CODE` on send,
//! `DEQ ∘ CODE` on receive, plus the QAda state machine (sufficient
//! statistics, level re-optimization, codec rebuild).
//!
//! One [`Compressor`] instance lives on each worker. Level updates must be
//! driven identically on every worker (the coordinator exchanges pooled
//! statistics first) so that all replicas hold the same levels/codec — the
//! decode side of the wire format depends on them.

use crate::coding::SymbolCodec;
use crate::config::{LevelScheme, QuantConfig, QuantMode};
use crate::error::{Error, Result};
use crate::quant::{
    decode_vector, dequantize_into, encode_vector, optimize_levels, quantize, symbol_probs,
    Levels, SufficientStats, WireCodec,
};
use crate::util::Rng;

/// A worker's (de)compression endpoint.
pub enum Compressor {
    /// Full precision: raw little-endian f32 payloads (32 bits/coordinate).
    Fp32,
    /// Quantize + entropy-code per the paper.
    Quant(Box<QuantCompressor>),
}

pub struct QuantCompressor {
    cfg: QuantConfig,
    levels: Levels,
    codec: WireCodec,
    rng: Rng,
    /// Local sufficient statistics for the *next* level update.
    stats: SufficientStats,
    /// Number of level updates performed (J counter).
    updates: usize,
}

impl Compressor {
    /// Build from config; `rng` seeds the quantization randomness (private
    /// per worker).
    pub fn from_config(cfg: &QuantConfig, rng: Rng) -> Result<Self> {
        match cfg.mode {
            QuantMode::Fp32 => Ok(Compressor::Fp32),
            QuantMode::Quantized { levels: s } => {
                let levels = initial_levels(cfg.scheme, s);
                let codec = build_codec(&levels, cfg.codec, None)?;
                Ok(Compressor::Quant(Box::new(QuantCompressor {
                    cfg: cfg.clone(),
                    levels,
                    codec,
                    rng,
                    stats: SufficientStats::new(cfg.hist_bins, cfg.norm_q),
                    updates: 0,
                })))
            }
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, Compressor::Quant(_))
    }

    /// Current levels (None for FP32).
    pub fn levels(&self) -> Option<&Levels> {
        match self {
            Compressor::Fp32 => None,
            Compressor::Quant(q) => Some(&q.levels),
        }
    }

    /// Theorem-1 variance factor of the current configuration.
    pub fn epsilon_q(&self, d: usize) -> f64 {
        match self {
            Compressor::Fp32 => 0.0,
            Compressor::Quant(q) => {
                let per_bucket = if q.cfg.bucket_size == 0 { d } else { q.cfg.bucket_size.min(d) };
                crate::quant::epsilon_q(&q.levels, per_bucket, q.cfg.norm_q)
            }
        }
    }

    /// Compress a dual vector; returns (wire bytes, exact payload bits).
    /// Also feeds the local sufficient statistics (QAda observes the *raw*
    /// vector, pre-quantization).
    pub fn compress(&mut self, v: &[f32]) -> Result<(Vec<u8>, u64)> {
        match self {
            Compressor::Fp32 => {
                let mut bytes = Vec::with_capacity(4 * v.len());
                for &x in v {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
                let bits = 32 * v.len() as u64;
                Ok((bytes, bits))
            }
            Compressor::Quant(q) => {
                // Sufficient statistics feed (a) QAda level optimization and
                // (b) Huffman probability refreshes — needed even when the
                // level placement itself is fixed. `stat_samples` caps how
                // many vectors (buckets, under bucketing) feed the statistic
                // per schedule segment, so stat upkeep stays O(cap) as `d`
                // and the segment length grow; 0 = unlimited.
                if q.cfg.adapts() {
                    let cap = q.cfg.stat_samples;
                    if cap == 0 {
                        q.stats.observe_bucketed(v, q.cfg.bucket_size);
                    } else if q.stats.vectors_seen() < cap {
                        let b =
                            if q.cfg.bucket_size == 0 { v.len() } else { q.cfg.bucket_size };
                        let room = cap - q.stats.vectors_seen();
                        let take = room.saturating_mul(b).min(v.len());
                        q.stats.observe_bucketed(&v[..take], q.cfg.bucket_size);
                    }
                }
                let qv =
                    quantize(v, &q.levels, q.cfg.norm_q, q.cfg.bucket_size, &mut q.rng)?;
                encode_vector(&qv, &q.codec)
            }
        }
    }

    /// Decompress a peer's wire bytes into `out` (length = d).
    pub fn decompress(&self, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        match self {
            Compressor::Fp32 => {
                if bytes.len() != 4 * out.len() {
                    return Err(Error::Codec(format!(
                        "fp32 payload {} bytes for d = {}",
                        bytes.len(),
                        out.len()
                    )));
                }
                for (i, c) in bytes.chunks_exact(4).enumerate() {
                    out[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                Ok(())
            }
            Compressor::Quant(q) => {
                let qv = decode_vector(bytes, out.len(), q.cfg.bucket_size, &q.codec)?;
                dequantize_into(&qv, &q.levels, out);
                Ok(())
            }
        }
    }

    /// Serialize local sufficient statistics for the stat exchange.
    ///
    /// Non-empty whenever *anything* adapts on the update schedule: QAda
    /// level placement (`scheme == Adaptive`) **or** the Huffman
    /// probability model (`codec == Huffman`, any level scheme) — the same
    /// condition under which [`Self::update_levels`] consumes the pooled
    /// payloads (both sides share [`QuantConfig::adapts`]). Gating on the
    /// scheme alone made Huffman-with-fixed-levels runs pay for stat
    /// rounds whose payloads were all empty, so the advertised probability
    /// refresh silently never happened.
    /// Empty for FP32 and for fully static pipelines.
    pub fn stats_payload(&self) -> Vec<u8> {
        match self {
            Compressor::Quant(q) if q.cfg.adapts() => q.stats.to_bytes(),
            _ => Vec::new(),
        }
    }

    /// Perform the level update from the *rank-ordered list of all workers'
    /// serialized statistics* (including this worker's own payload).
    ///
    /// Pooling exclusively from the serialized (f32-rounded) payloads in a
    /// fixed order — never from the in-memory f64 accumulator — guarantees
    /// every replica optimizes from bit-identical inputs and therefore
    /// lands on bit-identical levels and Huffman tables. Returns true if
    /// levels actually changed.
    pub fn update_levels(&mut self, all_stats_rank_order: &[&[u8]]) -> Result<bool> {
        let q = match self {
            Compressor::Fp32 => return Ok(false),
            Compressor::Quant(q) => q,
        };
        if !q.cfg.adapts() {
            return Ok(false);
        }
        let adapt_levels = q.cfg.scheme == LevelScheme::Adaptive;
        let mut pooled = SufficientStats::new(q.cfg.hist_bins, q.cfg.norm_q);
        for p in all_stats_rank_order {
            if !p.is_empty() {
                pooled.absorb_bytes(p)?;
            }
        }
        if pooled.is_empty() {
            return Ok(false);
        }
        let new_levels = if adapt_levels {
            optimize_levels(&pooled, q.levels.s(), Some(&q.levels), 8)?
        } else {
            q.levels.clone()
        };
        let probs = symbol_probs(&pooled, &new_levels);
        q.codec = build_codec(&new_levels, q.cfg.codec, Some(&probs))?;
        let changed = new_levels != q.levels;
        q.levels = new_levels;
        q.stats.reset();
        q.updates += 1;
        Ok(changed)
    }

    /// Number of level updates performed so far (the `J` of Theorems 3/4).
    pub fn updates(&self) -> usize {
        match self {
            Compressor::Fp32 => 0,
            Compressor::Quant(q) => q.updates,
        }
    }
}

fn initial_levels(scheme: LevelScheme, s: usize) -> Levels {
    match scheme {
        LevelScheme::Uniform => Levels::uniform(s),
        LevelScheme::Exponential => Levels::exponential(s),
        // Adaptive starts from exponential (a decent prior for gradient
        // coordinates) and re-optimizes on schedule. For large alphabets
        // exponential spacing underflows f32 near zero (2^-s), so fall back
        // to uniform there.
        LevelScheme::Adaptive => {
            if s <= 32 {
                Levels::exponential(s)
            } else {
                Levels::uniform(s)
            }
        }
    }
}

fn build_codec(levels: &Levels, kind: SymbolCodec, probs: Option<&[f64]>) -> Result<WireCodec> {
    match kind {
        SymbolCodec::Huffman => match probs {
            Some(p) => WireCodec::new(kind, levels, Some(p)),
            // Before the first stat exchange there is no probability
            // estimate; bootstrap with a geometric prior over symbols
            // (favors small levels like gradients do).
            None => {
                let n = levels.alphabet_size();
                let prior: Vec<f64> = (0..n).map(|j| 0.5f64.powi(j.min(60) as i32)).collect();
                WireCodec::new(kind, levels, Some(&prior))
            }
        },
        _ => WireCodec::new(kind, levels, None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_allclose;
    use crate::util::Rng;

    fn quant_cfg(scheme: LevelScheme, codec: SymbolCodec) -> QuantConfig {
        QuantConfig {
            mode: QuantMode::Quantized { levels: 14 },
            scheme,
            norm_q: 2,
            bucket_size: 256,
            codec,
            update_every: 50,
            hist_bins: 128,
            stat_samples: 8,
        }
    }

    #[test]
    fn fp32_roundtrip_is_exact() {
        let mut c = Compressor::from_config(
            &QuantConfig { mode: QuantMode::Fp32, ..Default::default() },
            Rng::seed_from(1),
        )
        .unwrap();
        let v = Rng::seed_from(2).gaussian_vec(100, 1.0);
        let (bytes, bits) = c.compress(&v).unwrap();
        assert_eq!(bits, 3200);
        let mut out = vec![0.0f32; 100];
        c.decompress(&bytes, &mut out).unwrap();
        assert_eq!(v, out);
        assert_eq!(c.epsilon_q(100), 0.0);
    }

    #[test]
    fn quantized_roundtrip_approximates() {
        for codec in [SymbolCodec::Fixed, SymbolCodec::EliasGamma, SymbolCodec::Huffman] {
            let mut c = Compressor::from_config(
                &quant_cfg(LevelScheme::Uniform, codec),
                Rng::seed_from(3),
            )
            .unwrap();
            let v = Rng::seed_from(4).gaussian_vec(512, 1.0);
            let (bytes, bits) = c.compress(&v).unwrap();
            assert!(bits < 32 * 512, "must beat fp32: {bits}");
            let mut out = vec![0.0f32; 512];
            c.decompress(&bytes, &mut out).unwrap();
            // Unbiased noisy reconstruction: close in norm, not exact.
            let err = crate::util::dist_sq(&v, &out).sqrt();
            let nv = crate::util::norm2(&v);
            assert!(err < nv, "err {err} vs ‖v‖ {nv} ({codec:?})");
        }
    }

    #[test]
    fn sender_receiver_pairs_interoperate() {
        // Worker A compresses; worker B (separate instance, same config)
        // decompresses — the distributed wire contract.
        let cfg = quant_cfg(LevelScheme::Exponential, SymbolCodec::EliasGamma);
        let mut a = Compressor::from_config(&cfg, Rng::seed_from(5)).unwrap();
        let b = Compressor::from_config(&cfg, Rng::seed_from(6)).unwrap();
        let v = Rng::seed_from(7).gaussian_vec(300, 2.0);
        let (bytes, _) = a.compress(&v).unwrap();
        let mut out = vec![0.0f32; 300];
        b.decompress(&bytes, &mut out).unwrap();
        // B's decode must equal A's own decode exactly.
        let mut out_a = vec![0.0f32; 300];
        a.decompress(&bytes, &mut out_a).unwrap();
        assert_allclose(&out, &out_a, 0.0, 0.0);
    }

    #[test]
    fn adaptive_update_changes_levels_and_stays_in_sync() {
        let cfg = quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        let mut a = Compressor::from_config(&cfg, Rng::seed_from(8)).unwrap();
        let mut b = Compressor::from_config(&cfg, Rng::seed_from(9)).unwrap();
        let mut rng = Rng::seed_from(10);
        for _ in 0..20 {
            let v = rng.gaussian_vec(1024, 1.0);
            let _ = a.compress(&v).unwrap();
            let v2 = rng.gaussian_vec(1024, 1.0);
            let _ = b.compress(&v2).unwrap();
        }
        // Exchange stats; both update with the same pooled payloads.
        let sa = a.stats_payload();
        let sb = b.stats_payload();
        assert!(!sa.is_empty());
        let changed_a = a.update_levels(&[&sa, &sb]).unwrap();
        let changed_b = b.update_levels(&[&sa, &sb]).unwrap();
        assert!(changed_a && changed_b);
        assert_eq!(a.levels().unwrap(), b.levels().unwrap());
        assert_eq!(a.updates(), 1);
        // Cross-decode still works after the update.
        let v = rng.gaussian_vec(1024, 1.0);
        let (bytes, _) = a.compress(&v).unwrap();
        let mut out = vec![0.0f32; 1024];
        b.decompress(&bytes, &mut out).unwrap();
    }

    #[test]
    fn adaptive_levels_reduce_wire_size_via_huffman() {
        let cfg = quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(11)).unwrap();
        let mut rng = Rng::seed_from(12);
        let mut before_bits = 0u64;
        for _ in 0..10 {
            let v = rng.gaussian_vec(4096, 1.0);
            let (_, bits) = c.compress(&v).unwrap();
            before_bits = bits;
        }
        let own = c.stats_payload();
        c.update_levels(&[&own]).unwrap();
        let v = rng.gaussian_vec(4096, 1.0);
        let (_, after_bits) = c.compress(&v).unwrap();
        // With a proper probability model the Huffman stream shrinks
        // relative to the bootstrap prior (or at worst stays similar).
        assert!(
            (after_bits as f64) < before_bits as f64 * 1.1,
            "after {after_bits} vs before {before_bits}"
        );
    }

    #[test]
    fn huffman_fixed_levels_refresh_is_not_a_noop() {
        // Regression: Huffman with *fixed* (uniform) levels used to return
        // an empty stats payload, so the scheduled "codec refresh" pooled
        // nothing and silently kept the bootstrap prior forever.
        let cfg = quant_cfg(LevelScheme::Uniform, SymbolCodec::Huffman);
        let mut refreshed = Compressor::from_config(&cfg, Rng::seed_from(21)).unwrap();
        let mut bootstrap = Compressor::from_config(&cfg, Rng::seed_from(21)).unwrap();
        let mut rng = Rng::seed_from(22);
        for _ in 0..12 {
            let v = rng.gaussian_vec(2048, 1.0);
            let _ = refreshed.compress(&v).unwrap();
            let _ = bootstrap.compress(&v).unwrap();
        }
        let payload = refreshed.stats_payload();
        assert!(!payload.is_empty(), "fixed-levels Huffman must ship stats");
        let changed = refreshed.update_levels(&[&payload]).unwrap();
        assert!(!changed, "uniform level placement must not move");
        assert_eq!(refreshed.updates(), 1, "the refresh must count as an update");
        assert_eq!(refreshed.levels().unwrap(), bootstrap.levels().unwrap());
        // Identical seeds + identical levels => both compressors consumed
        // the same uniforms and emit the same symbols for the same input;
        // any wire-size difference below is purely the rebuilt Huffman
        // table. With a fitted probability model it must beat the
        // bootstrap geometric prior on in-distribution data.
        let v = rng.gaussian_vec(2048, 1.0);
        let (_, bits_refreshed) = refreshed.compress(&v).unwrap();
        let (_, bits_bootstrap) = bootstrap.compress(&v).unwrap();
        assert!(
            bits_refreshed < bits_bootstrap,
            "refreshed table must shrink the stream: {bits_refreshed} vs {bits_bootstrap}"
        );
    }

    #[test]
    fn stat_samples_caps_observed_vectors_per_segment() {
        // The `quant.stat_samples` knob is the per-segment cap on vectors
        // (buckets) absorbed into the sufficient statistic.
        let mut cfg = quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        cfg.stat_samples = 3;
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(30)).unwrap();
        let mut rng = Rng::seed_from(31);
        for _ in 0..5 {
            // 512 coords / 256 bucket = 2 buckets per compress
            let v = rng.gaussian_vec(512, 1.0);
            let _ = c.compress(&v).unwrap();
        }
        // Payload header (wire format v2) carries the pooled vector count.
        let payload = c.stats_payload();
        let seen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
        assert_eq!(seen, 3, "cap must stop stat intake exactly at stat_samples");
        // After an update the segment (and the counter) restarts.
        c.update_levels(&[&payload]).unwrap();
        let v = rng.gaussian_vec(512, 1.0);
        let _ = c.compress(&v).unwrap();
        let payload = c.stats_payload();
        let seen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
        assert_eq!(seen, 2, "new segment observes again up to the cap");
        // cap = 0 means unlimited
        let mut cfg0 = quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        cfg0.stat_samples = 0;
        let mut c0 = Compressor::from_config(&cfg0, Rng::seed_from(32)).unwrap();
        for _ in 0..5 {
            let v = rng.gaussian_vec(512, 1.0);
            let _ = c0.compress(&v).unwrap();
        }
        let payload = c0.stats_payload();
        let seen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
        assert_eq!(seen, 10);
    }

    #[test]
    fn fp32_stat_payload_is_empty_and_update_is_noop() {
        let mut c = Compressor::from_config(
            &QuantConfig { mode: QuantMode::Fp32, ..Default::default() },
            Rng::seed_from(13),
        )
        .unwrap();
        assert!(c.stats_payload().is_empty());
        assert!(!c.update_levels(&[]).unwrap());
    }

    #[test]
    fn decompress_validates_length() {
        let c = Compressor::from_config(
            &QuantConfig { mode: QuantMode::Fp32, ..Default::default() },
            Rng::seed_from(14),
        )
        .unwrap();
        let mut out = vec![0.0f32; 4];
        assert!(c.decompress(&[0u8; 7], &mut out).is_err());
    }
}
