#!/usr/bin/env python3
"""Bench regression gate over committed BENCH_*.json baselines (stdlib only).

Compares a fresh bench emission against the committed baseline and fails
on regression. The gate dispatches on the file's `bench` field:

  python3 tools/perf_gate.py ../results/BENCH_hotpath.json results/BENCH_hotpath.json
  python3 tools/perf_gate.py ../results/BENCH_churn.json   results/BENCH_churn.json

## hotpath gates, from hard to soft

* **schema / shape** — same `bench`, same `schema` version, identical
  case set keyed by (stage, quant, codec, bucket). A vanished case is a
  regression (a stage or codec stopped being measured).
* **allocations (exact)** — every case the baseline records at
  0 allocs/message must still be 0; the compressor round trip must be 0.
  These are machine-independent and gate bit-exactly.
* **huffman decode speedup (floor)** — `huffman_decode_speedup_min` must
  stay >= PERF_GATE_SPEEDUP_MIN (default 2.0, the documented >= 2x LUT
  criterion in docs/PERF.md).
* **timing ratios (tolerance band)** — per-case and round-trip
  `ns_per_coord` must stay <= baseline * PERF_GATE_TOL (default 10.0).
  The band is deliberately wide: CI runners are shared and noisy, so this
  catches order-of-magnitude hot-path regressions (an accidental
  per-symbol allocation, a debug-path fallback), not single-digit noise.
  Ratios only apply when both files ran the same `mode` (fast vs full).

## churn gates (all machine-independent)

* **schema / shape** — same `bench`, same `schema`; identical sweep-point
  sets (straggler rates and rewire cadences). A vanished sweep point is a
  regression — the chaos axis stopped being measured.
* **finiteness** — every fresh sweep point's `gap` must be finite
  (degradation curves may move, divergence may not).

## ef gates (all machine-independent)

* **schema / shape** — same `bench`, same `schema`; identical config set
  keyed by (oracle, config name). A vanished compressor config is a
  regression — the error-feedback axis stopped being measured.
* **finiteness** — every fresh config's `final_gap` and `bits_at_gap`
  must be finite (the compressor may move the curve, not diverge it).
* **floor (full mode only)** — when the fresh run is full-scale, at
  least one contractive config must reach the matched gap on `lm-proxy`
  with strictly fewer bits than the unbiased floor config (the bench's
  headline claim, re-asserted against the fresh numbers).

Environment overrides: PERF_GATE_TOL, PERF_GATE_SPEEDUP_MIN.
Exit status: 0 = pass, 1 = regression(s), 2 = usage/parse error.
"""

import json
import math
import os
import sys


def key(case):
    return (case["stage"], case["quant"], case["codec"], case["bucket"])


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"perf_gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def check_shape(base, fresh, failures):
    for field in ("bench", "schema"):
        if base.get(field) != fresh.get(field):
            failures.append(
                f"{field} mismatch: baseline {base.get(field)!r} vs fresh {fresh.get(field)!r}"
            )


def gate_hotpath(base, fresh, failures):
    tol = float(os.environ.get("PERF_GATE_TOL", "10.0"))
    speedup_min = float(os.environ.get("PERF_GATE_SPEEDUP_MIN", "2.0"))

    base_cases = {key(c): c for c in base.get("cases", [])}
    fresh_cases = {key(c): c for c in fresh.get("cases", [])}
    for k in sorted(set(base_cases) - set(fresh_cases)):
        failures.append(f"case vanished from fresh run: {k}")
    for k in sorted(set(fresh_cases) - set(base_cases)):
        # New cases are fine (a new codec under test) but worth surfacing.
        print(f"note: new case not in baseline: {k}")

    # -- allocations (machine-independent, exact) --------------------------
    for k in sorted(set(base_cases) & set(fresh_cases)):
        b, f = base_cases[k], fresh_cases[k]
        if b.get("allocs_per_message") == 0 and f.get("allocs_per_message") != 0:
            failures.append(
                f"{k}: allocs/message regressed 0 -> {f.get('allocs_per_message')}"
            )
    rt = fresh.get("roundtrip", {})
    if rt.get("allocs_per_message") != 0:
        failures.append(
            f"roundtrip allocs/message must be 0, got {rt.get('allocs_per_message')}"
        )

    # -- huffman decode speedup floor --------------------------------------
    got = fresh.get("huffman_decode_speedup_min", 0.0)
    if got < speedup_min:
        failures.append(
            f"huffman_decode_speedup_min {got:.2f}x below floor {speedup_min:.2f}x"
        )

    # -- timing ratios (same-mode runs only) -------------------------------
    if base.get("mode") == fresh.get("mode"):
        checked = 0
        for k in sorted(set(base_cases) & set(fresh_cases)):
            b_ns = base_cases[k].get("ns_per_coord")
            f_ns = fresh_cases[k].get("ns_per_coord")
            if not b_ns or f_ns is None:
                continue
            checked += 1
            if f_ns > b_ns * tol:
                failures.append(
                    f"{k}: ns/coord {f_ns:.2f} vs baseline {b_ns:.2f} "
                    f"(> {tol:.1f}x tolerance)"
                )
        b_rt = base.get("roundtrip", {}).get("ns_per_coord")
        f_rt = rt.get("ns_per_coord")
        if b_rt and f_rt is not None and f_rt > b_rt * tol:
            failures.append(
                f"roundtrip: ns/coord {f_rt:.2f} vs baseline {b_rt:.2f} "
                f"(> {tol:.1f}x tolerance)"
            )
        print(
            f"timing: {checked} cases + roundtrip within {tol:.1f}x of baseline"
            if not any("tolerance" in f for f in failures)
            else f"timing: regressions found (tolerance {tol:.1f}x)"
        )
    else:
        print(
            f"timing: skipped ratio checks (baseline mode {base.get('mode')!r} "
            f"vs fresh {fresh.get('mode')!r})"
        )

    if not failures:
        print(
            f"perf_gate: ok — {len(fresh_cases)} cases, "
            f"huffman decode speedup min {got:.2f}x, round-trip allocs 0"
        )


def gate_churn(base, fresh, failures):
    sweeps = (("straggler_curve", "rate"), ("rewire_curve", "rewire_every"))
    points = 0
    for curve, axis in sweeps:
        base_pts = {p[axis] for p in base.get(curve, [])}
        fresh_pts = {p[axis] for p in fresh.get(curve, [])}
        for p in sorted(base_pts - fresh_pts):
            failures.append(f"{curve}: sweep point vanished from fresh run: {axis}={p}")
        for p in sorted(fresh_pts - base_pts):
            print(f"note: new sweep point not in baseline: {curve} {axis}={p}")
        for p in fresh.get(curve, []):
            points += 1
            gap = p.get("gap")
            if gap is None or not math.isfinite(gap):
                failures.append(f"{curve} {axis}={p.get(axis)}: non-finite gap {gap!r}")
    if not failures:
        print(f"perf_gate: ok — churn case set intact ({points} sweep points, all finite)")


def gate_ef(base, fresh, failures):
    base_cfgs = {
        (c["oracle"], cfg["name"])
        for c in base.get("curves", [])
        for cfg in c.get("configs", [])
    }
    fresh_curves = {c.get("oracle"): c.get("configs", []) for c in fresh.get("curves", [])}
    fresh_cfgs = {(o, cfg["name"]) for o, cfgs in fresh_curves.items() for cfg in cfgs}
    for k in sorted(base_cfgs - fresh_cfgs):
        failures.append(f"config vanished from fresh run: {k}")
    for k in sorted(fresh_cfgs - base_cfgs):
        print(f"note: new config not in baseline: {k}")

    for oracle, cfgs in fresh_curves.items():
        for cfg in cfgs:
            for field in ("final_gap", "bits_at_gap"):
                v = cfg.get(field)
                if v is None or not math.isfinite(v):
                    failures.append(f"{oracle}/{cfg.get('name')}: non-finite {field} {v!r}")

    if fresh.get("mode") == "full":
        lm = {cfg["name"]: cfg for cfg in fresh_curves.get("lm-proxy", [])}
        floor = lm.get("uq4-huffman")
        ef = [c for n, c in lm.items() if n != "uq4-huffman"]
        if floor is None or not ef:
            failures.append("lm-proxy floor/contractive configs missing from full run")
        elif not any(c["bits_at_gap"] < floor["bits_at_gap"] for c in ef):
            failures.append(
                "no contractive config beats the unbiased floor on lm-proxy "
                f"(floor bits_at_gap {floor['bits_at_gap']:.3e})"
            )
    else:
        print(f"floor check: skipped (fresh mode {fresh.get('mode')!r}, needs 'full')")

    if not failures:
        print(f"perf_gate: ok — ef config set intact ({len(fresh_cfgs)} configs, all finite)")


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    base = load(sys.argv[1])
    fresh = load(sys.argv[2])
    failures = []

    check_shape(base, fresh, failures)
    bench = base.get("bench")
    if bench == "churn_degradation":
        gate_churn(base, fresh, failures)
    elif bench == "ef_tradeoff":
        gate_ef(base, fresh, failures)
    elif bench == "perf_hotpath":
        gate_hotpath(base, fresh, failures)
    else:
        print(f"perf_gate: no gate for bench {bench!r}", file=sys.stderr)
        sys.exit(2)

    if failures:
        print(f"\nperf_gate: {len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
