//! Walkthrough: local extra-gradient steps with periodic quantized
//! delta synchronization — the third scenario family next to the exact
//! and gossip runners.
//!
//! With `[local] steps = H`, each worker runs `H` extra-gradient
//! iterations against its *private* stochastic oracle, then the replicas
//! exchange quantized **model deltas** over the configured topology and
//! re-synchronize by averaging. Communication drops from one-to-two dual
//! rounds per iteration to one delta round per `H` iterations; the cost
//! is intra-segment replica drift, which the `sync_drift` series tracks.
//! `H = 1` is exactly the seed algorithm (per-step dual exchange,
//! bit-for-bit).
//!
//! ```bash
//! cargo run --release --example local_steps
//! ```

use qgenx::benchkit::example_iters;
use qgenx::config::ExperimentConfig;
use qgenx::coordinator::run_threaded;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "local_steps".into();
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 64;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.5;
    cfg.workers = 8;
    cfg.iters = example_iters(400);
    cfg.eval_every = (cfg.iters / 4).max(1);

    println!(
        "Q-GenX, quadratic VI d={} K={} workers, uq4 adaptive quantization.",
        cfg.problem.dim, cfg.workers
    );
    println!("Same iteration budget, varying local steps H (threaded coordinator):\n");
    println!(
        "{:<4} {:>10} {:>12} {:>8} {:>14} {:>12}",
        "H", "final gap", "wire MiB", "syncs", "drift/sync", "sim net ms"
    );

    let mut prev_bits = f64::INFINITY;
    for h in [1usize, 2, 4, 8] {
        cfg.local.steps = h;
        let run = run_threaded(&cfg)?;
        let rec = &run.recorder;
        let gap = rec.get("gap").and_then(|s| s.last()).unwrap_or(f64::NAN);
        let bits = rec.scalar("total_bits").unwrap_or(0.0);
        let mib = bits / 8.0 / 1048576.0;
        let syncs = rec.scalar("syncs").unwrap_or(0.0);
        let drift = rec.scalar("mean_sync_drift").unwrap_or(0.0);
        let net_ms = rec.scalar("sim_net_time").unwrap_or(0.0) * 1e3;
        println!("{h:<4} {gap:>10.5} {mib:>12.3} {syncs:>8.0} {drift:>14.5} {net_ms:>12.3}");

        // Fewer communication rounds at the same iteration budget must put
        // strictly fewer bits on the wire.
        assert!(bits < prev_bits, "H = {h} must cut wire traffic");
        prev_bits = bits;

        // Exact topology: replicas re-converge exactly at the final sync.
        for r in &run.replicas[1..] {
            assert_eq!(r, &run.replicas[0], "replicas must agree after the final sync");
        }
    }

    println!(
        "\nReading the table:\n\
         * H = 1 is the seed per-step dual exchange (two rounds per iteration\n\
           under dual extrapolation); H >= 2 exchanges one quantized delta per\n\
           worker per H iterations — wire traffic falls roughly as 1/(2H);\n\
         * `drift/sync` is the consensus distance the private oracles open up\n\
           within each local segment; the averaging sync closes it, and the\n\
           final gap degrades only mildly while the bits plummet;\n\
         * the delta payloads go through the same CODE∘Q pipeline (and the\n\
           same [topo] collectives) as the dual exchanges, so local steps,\n\
           compression, and topology compose as independent axes.\n\
         \n\
         Try `[local]` in a config file (steps = H) or `qgenx run --local 8`,\n\
         and `cargo bench --bench local_steps` for the matched-gap accounting."
    );
    Ok(())
}
