"""L2 model correctness: shapes, gradient sanity, learnability, GAN losses."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model


CFG = model.LM_PRESETS["small"]


def make_tokens(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq)).astype(np.int32)


class TestPacker:
    def test_roundtrip(self):
        p = model.Packer()
        p.add("a", (2, 3))
        p.add("b", (4,))
        assert p.total == 10
        flat = p.pack({"a": np.arange(6).reshape(2, 3), "b": np.ones(4)})
        a = p.get(jnp.array(flat), "a")
        assert a.shape == (2, 3)
        np.testing.assert_array_equal(np.asarray(a), np.arange(6).reshape(2, 3))
        b = p.get(jnp.array(flat), "b")
        np.testing.assert_array_equal(np.asarray(b), np.ones(4))

    def test_lm_param_counts_scale_with_preset(self):
        small = model.lm_param_count(model.LM_PRESETS["small"])
        medium = model.lm_param_count(model.LM_PRESETS["medium"])
        large = model.lm_param_count(model.LM_PRESETS["large"])
        assert small < medium < large
        assert large > 15_000_000, f"large preset too small: {large}"


class TestLM:
    def test_loss_near_log_vocab_at_init(self):
        params = model.lm_init(CFG, seed=0)
        tokens = make_tokens(CFG)
        loss = float(model.lm_loss(jnp.array(params), jnp.array(tokens), CFG))
        expected = np.log(CFG.vocab)
        assert abs(loss - expected) < 0.5, f"init loss {loss} vs log V {expected}"

    def test_step_returns_finite_grads_of_right_shape(self):
        params = model.lm_init(CFG, seed=1)
        tokens = make_tokens(CFG, 1)
        loss, grads = model.lm_step(jnp.array(params), jnp.array(tokens), CFG)
        assert grads.shape == (model.lm_param_count(CFG),)
        assert np.isfinite(float(loss))
        g = np.asarray(grads)
        assert np.all(np.isfinite(g))
        assert np.linalg.norm(g) > 0

    def test_sgd_reduces_loss_on_fixed_batch(self):
        params = jnp.array(model.lm_init(CFG, seed=2))
        tokens = jnp.array(make_tokens(CFG, 2))
        step = jax.jit(lambda p, t: model.lm_step(p, t, CFG))
        loss0, _ = step(params, tokens)
        lr = 0.5
        for _ in range(20):
            _, g = step(params, tokens)
            params = params - lr * g
        loss1, _ = step(params, tokens)
        assert float(loss1) < float(loss0) * 0.9, f"{float(loss0)} -> {float(loss1)}"

    def test_causality(self):
        # Changing a future token must not change the loss contribution of
        # earlier positions: compare per-position logits directly.
        params = jnp.array(model.lm_init(CFG, seed=3))
        t1 = make_tokens(CFG, 3)
        t2 = t1.copy()
        t2[:, -1] = (t2[:, -1] + 1) % CFG.vocab

        def per_pos_nll(tokens):
            # reuse lm_loss internals by probing the loss with matched
            # prefixes: losses over [:, :-1] predictions of positions <k
            # must agree. We check the total loss difference comes only
            # from the final target.
            return model.lm_loss(params, jnp.array(tokens), CFG)

        # mask trick: losses with identical prefixes differ only through the
        # last column's target term, bounded by max |logp| over one token.
        l1 = float(per_pos_nll(t1))
        l2 = float(per_pos_nll(t2))
        n_terms = CFG.batch * (CFG.seq - 1)
        # Only batch-many target terms can differ:
        assert abs(l1 - l2) * n_terms <= CFG.batch * 50.0

    def test_deterministic_given_seed(self):
        p1 = model.lm_init(CFG, seed=7)
        p2 = model.lm_init(CFG, seed=7)
        np.testing.assert_array_equal(p1, p2)


class TestGAN:
    CFG = model.GanConfig(batch=64)

    def _inputs(self, seed=0):
        rng = np.random.default_rng(seed)
        tg, td = model.gan_init(self.CFG, seed=seed)
        real = model.ring_of_gaussians(self.CFG.batch, seed)
        z = rng.normal(size=(self.CFG.batch, self.CFG.nz)).astype(np.float32)
        eps = rng.random((self.CFG.batch, 1)).astype(np.float32)
        return jnp.array(td), jnp.array(tg), jnp.array(real), jnp.array(z), jnp.array(eps)

    def test_generator_output_shape(self):
        td, tg, real, z, eps = self._inputs()
        fake = model.generator(tg, z, self.CFG)
        assert fake.shape == (self.CFG.batch, 2)
        assert np.all(np.isfinite(np.asarray(fake)))

    def test_disc_and_gen_steps_finite(self):
        td, tg, real, z, eps = self._inputs(1)
        ld, gd = model.gan_disc_step(td, tg, real, z, eps, self.CFG)
        lg, gg = model.gan_gen_step(td, tg, z, self.CFG)
        assert np.isfinite(float(ld)) and np.isfinite(float(lg))
        assert gd.shape == (model.gan_param_counts(self.CFG)[1],)
        assert gg.shape == (model.gan_param_counts(self.CFG)[0],)
        assert np.all(np.isfinite(np.asarray(gd)))
        assert np.all(np.isfinite(np.asarray(gg)))

    def test_gradient_penalty_active(self):
        # With lambda = 0 the critic loss differs from lambda = 1.
        td, tg, real, z, eps = self._inputs(2)
        cfg0 = model.GanConfig(batch=64, gp_lambda=0.0)
        cfg1 = model.GanConfig(batch=64, gp_lambda=1.0)
        l0 = float(model.gan_disc_loss(td, tg, real, z, eps, cfg0))
        l1 = float(model.gan_disc_loss(td, tg, real, z, eps, cfg1))
        assert abs(l0 - l1) > 1e-6

    def test_adversarial_steps_move_losses(self):
        # A few alternating SGD steps: critic Wasserstein estimate grows in
        # magnitude (it learns to separate real from fake at init).
        td, tg, real, z, eps = self._inputs(3)
        disc = jax.jit(lambda d, g, r, zz, e: model.gan_disc_step(d, g, r, zz, e, self.CFG))
        l_first = None
        for i in range(30):
            ld, gd = disc(td, tg, real, z, eps)
            td = td - 0.05 * gd
            if l_first is None:
                l_first = float(ld)
        l_last = float(disc(td, tg, real, z, eps)[0])
        assert l_last < l_first, f"critic loss should fall: {l_first} -> {l_last}"

    def test_ring_of_gaussians_geometry(self):
        data = model.ring_of_gaussians(4000, seed=4, modes=8, radius=2.0, sigma=0.01)
        r = np.linalg.norm(data, axis=1)
        assert abs(float(np.mean(r)) - 2.0) < 0.05
        assert data.shape == (4000, 2)


class TestShapesAcrossPresets:
    @pytest.mark.parametrize("preset", ["small", "medium"])
    def test_presets_trace(self, preset):
        cfg = model.LM_PRESETS[preset]
        p = model.lm_param_count(cfg)
        tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
        params = jax.ShapeDtypeStruct((p,), jnp.float32)
        out = jax.eval_shape(lambda pp, tt: model.lm_step(pp, tt, cfg), params, tokens)
        assert out[0].shape == ()
        assert out[1].shape == (p,)
