//! The restricted gap function (the paper's performance measure):
//!
//! `Gap_C(x̂) = sup_{x ∈ C} ⟨A(x), x̂ − x⟩`
//!
//! with `C` a compact neighbourhood of a solution — here the Euclidean ball
//! `B(x*, r)`. By Proposition 1 the gap is nonnegative on `C` and zero
//! exactly at solutions.
//!
//! Evaluation strategy: all synthetic operators are affine, so with
//! `x = x* + r w`, `‖w‖ ≤ 1`,
//!
//! `⟨A(x), x̂ − x⟩ = ⟨A(x* + r w), x̂ − x* − r w⟩`
//!
//! is concave in `w` whenever the symmetric part of the Jacobian is PSD
//! (monotonicity!), so projected gradient **ascent** over the unit ball
//! converges to the sup. We run it from several restarts (including the
//! known maximizer of the skew case, `w ∝ J^T(x̂ − x*)`) and return the
//! best value — a certified *lower* bound that is tight in practice and
//! exact for the pure-skew case.

use super::problems::Operator;
use crate::util::{norm2, Rng};

/// Evaluator for `Gap_{B(center, radius)}`.
#[derive(Clone)]
pub struct GapEvaluator {
    center: Vec<f32>,
    radius: f64,
    /// ascent iterations per restart
    iters: usize,
    restarts: usize,
}

impl GapEvaluator {
    /// `C = B(center, radius)`; `center` should be (near) a solution for
    /// Proposition 1 to give Gap = 0 exactly at solutions.
    pub fn new(center: Vec<f32>, radius: f64) -> Self {
        GapEvaluator { center, radius, iters: 60, restarts: 4 }
    }

    /// Build around the operator's known solution.
    pub fn around_solution(op: &dyn Operator, radius: f64) -> Option<Self> {
        op.solution().map(|s| Self::new(s, radius))
    }

    pub fn radius(&self) -> f64 {
        self.radius
    }

    /// Objective `φ(w) = ⟨A(x* + r w), x̂ − x* − r w⟩` for `‖w‖ ≤ 1`.
    fn phi(&self, op: &dyn Operator, x_hat: &[f32], w: &[f32], buf: &mut GapBufs) -> f64 {
        let d = self.center.len();
        for i in 0..d {
            buf.x[i] = self.center[i] + (self.radius * w[i] as f64) as f32;
        }
        op.apply(&buf.x, &mut buf.ax);
        let mut acc = 0.0f64;
        for i in 0..d {
            let diff = x_hat[i] as f64 - buf.x[i] as f64;
            acc += buf.ax[i] as f64 * diff;
        }
        acc
    }

    /// Evaluate the gap at `x_hat`.
    ///
    /// Uses exact line search along analytic candidate directions: since
    /// `φ` is a quadratic polynomial along any line `w(t) = (1−t) w0 + t w1`
    /// (affine `A`), we can maximize it on `t ∈ [0, 1]` from three point
    /// evaluations — no gradients needed, black-box safe.
    pub fn gap(&self, op: &dyn Operator, x_hat: &[f32]) -> f64 {
        let d = self.center.len();
        assert_eq!(x_hat.len(), d);
        let mut buf = GapBufs::new(d);
        let mut rng = Rng::seed_from(0x6a9);

        // Candidate starting directions.
        let mut candidates: Vec<Vec<f32>> = Vec::new();
        // (a) toward x̂: w ∝ x̂ − x*  — maximizes the ⟨·⟩ for shrinking ops.
        let delta: Vec<f32> = x_hat.iter().zip(self.center.iter()).map(|(a, b)| a - b).collect();
        let nd = norm2(&delta);
        if nd > 0.0 {
            candidates.push(delta.iter().map(|&v| (v as f64 / nd) as f32).collect());
            candidates.push(delta.iter().map(|&v| (-(v as f64) / nd) as f32).collect());
        }
        // (b) skew-optimal direction: w ∝ Jᵀ δ computed by finite
        //     difference of ⟨A(x* + h u), δ⟩ over random u refined by two
        //     power-iteration-ish passes.
        // (c) random restarts.
        for _ in 0..self.restarts {
            let mut w = rng.gaussian_vec(d, 1.0);
            let n = norm2(&w);
            if n > 0.0 {
                for v in w.iter_mut() {
                    *v = (*v as f64 / n) as f32;
                }
                candidates.push(w);
            }
        }
        candidates.push(vec![0.0f32; d]); // center of C

        let mut best = f64::NEG_INFINITY;
        for w0 in &candidates {
            let mut w = w0.clone();
            let mut val = self.phi(op, x_hat, &w, &mut buf);
            // Coordinate-free hill climb: repeatedly line-search toward a
            // fresh candidate direction; quadratic-exact 3-point search.
            for it in 0..self.iters {
                // direction: mix of delta and random
                let mut dir = rng.gaussian_vec(d, 1.0);
                if it % 2 == 0 && nd > 0.0 {
                    for i in 0..d {
                        dir[i] += delta[i] / nd as f32 * 2.0;
                    }
                }
                let ndir = norm2(&dir);
                if ndir == 0.0 {
                    continue;
                }
                for v in dir.iter_mut() {
                    *v = (*v as f64 / ndir) as f32;
                }
                // Candidate endpoint on the ball boundary.
                let w1 = dir;
                // φ along w(t) = normalize((1−t) w + t w1) is not quadratic
                // due to the normalization; instead search the chord and
                // project: evaluate at t ∈ {0, 1/2, 1}, fit quadratic, take
                // argmax, project to ball.
                let eval = |t: f64, buf: &mut GapBufs, w: &[f32], w1: &[f32]| {
                    let mut wt: Vec<f32> =
                        w.iter().zip(w1.iter()).map(|(a, b)| ((1.0 - t) * *a as f64 + t * *b as f64) as f32).collect();
                    let n = norm2(&wt);
                    if n > 1.0 {
                        for v in wt.iter_mut() {
                            *v = (*v as f64 / n) as f32;
                        }
                    }
                    (self.phi(op, x_hat, &wt, buf), wt)
                };
                let f0 = val;
                let (fh, wh) = eval(0.5, &mut buf, &w, &w1);
                let (f1, wfull) = eval(1.0, &mut buf, &w, &w1);
                // quadratic fit through (0,f0), (.5,fh), (1,f1)
                let a = 2.0 * f0 - 4.0 * fh + 2.0 * f1;
                let b = -3.0 * f0 + 4.0 * fh - f1;
                let t_star = if a < -1e-18 { (-b / (2.0 * a)).clamp(0.0, 1.0) } else { 1.0 };
                let (fs, ws) = eval(t_star, &mut buf, &w, &w1);
                let (bf, bw) = if fs >= fh && fs >= f1 {
                    (fs, ws)
                } else if fh >= f1 {
                    (fh, wh)
                } else {
                    (f1, wfull)
                };
                if bf > val {
                    val = bf;
                    w = bw;
                }
            }
            best = best.max(val);
        }
        best.max(0.0)
    }

    /// Distance to the center (≈ solution) — the simpler metric used by
    /// Figure-4-style comparisons.
    pub fn dist_to_center(&self, x_hat: &[f32]) -> f64 {
        crate::util::dist_sq(x_hat, &self.center).sqrt()
    }
}

struct GapBufs {
    x: Vec<f32>,
    ax: Vec<f32>,
}

impl GapBufs {
    fn new(d: usize) -> Self {
        GapBufs { x: vec![0.0; d], ax: vec![0.0; d] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::problems::{BilinearSaddle, MonotoneQuadratic, Operator};
    use crate::util::Rng;

    #[test]
    fn gap_zero_at_solution() {
        let mut rng = Rng::seed_from(1);
        let op = MonotoneQuadratic::random(8, 0.2, 1.0, &mut rng).unwrap();
        let xs = op.solution().unwrap();
        let ev = GapEvaluator::around_solution(&op, 2.0).unwrap();
        let g = ev.gap(&op, &xs);
        assert!(g.abs() < 1e-4, "gap at solution = {g}");
    }

    #[test]
    fn gap_positive_away_from_solution() {
        let mut rng = Rng::seed_from(2);
        let op = MonotoneQuadratic::random(8, 0.2, 1.0, &mut rng).unwrap();
        let mut x = op.solution().unwrap();
        x[0] += 1.0;
        let ev = GapEvaluator::around_solution(&op, 2.0).unwrap();
        let g = ev.gap(&op, &x);
        assert!(g > 0.05, "gap = {g}");
    }

    #[test]
    fn gap_decreases_toward_solution() {
        let mut rng = Rng::seed_from(3);
        let op = BilinearSaddle::random(8, 1.0, &mut rng).unwrap();
        let xs = op.solution().unwrap();
        let ev = GapEvaluator::around_solution(&op, 2.0).unwrap();
        let mut far = xs.clone();
        let mut near = xs.clone();
        for i in 0..far.len() {
            far[i] += 1.0;
            near[i] += 0.05;
        }
        let gf = ev.gap(&op, &far);
        let gn = ev.gap(&op, &near);
        assert!(gf > gn, "far {gf} should exceed near {gn}");
        assert!(gn >= 0.0);
    }

    #[test]
    fn skew_gap_matches_closed_form() {
        // For pure skew A(x)=J(x−x*), ⟨A(x*+rw), x̂−x*−rw⟩ = ⟨Jrw, δ⟩ −
        // r²⟨Jw,w⟩ = r⟨Jw, δ⟩ (skew kills the quadratic term), so
        // Gap = r‖Jᵀδ‖.
        let mut rng = Rng::seed_from(4);
        let op = BilinearSaddle::random(6, 1.0, &mut rng).unwrap();
        let xs = op.solution().unwrap();
        let d = op.dim();
        let mut x_hat = xs.clone();
        for (i, v) in x_hat.iter_mut().enumerate() {
            *v += 0.1 * (i as f32 + 1.0);
        }
        // J^T δ via operator: A is affine with A(x*)=0, so J u = A(x* + u).
        // For skew J, ‖Jᵀδ‖ = ‖Jδ‖.
        let delta: Vec<f32> = x_hat.iter().zip(xs.iter()).map(|(a, b)| a - b).collect();
        let mut jd = vec![0.0f32; d];
        let shifted: Vec<f32> = xs.iter().zip(delta.iter()).map(|(a, b)| a + b).collect();
        op.apply(&shifted, &mut jd);
        let r = 1.5;
        let closed = r * crate::util::norm2(&jd);
        let ev = GapEvaluator::new(xs, r);
        let est = ev.gap(&op, &x_hat);
        // Estimator is a lower bound; should reach >=80% of the closed form.
        assert!(est <= closed * 1.05, "est {est} closed {closed}");
        assert!(est >= 0.8 * closed, "est {est} too far below closed {closed}");
    }

    #[test]
    fn dist_metric() {
        let ev = GapEvaluator::new(vec![0.0; 3], 1.0);
        assert!((ev.dist_to_center(&[3.0, 0.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
