//! E10 — topology × quantizer trade-off over the α-β model.
//!
//! The paper's Algorithm 1 fixes one communication pattern (flat
//! all-to-all); this bench poses the question the topo subsystem opens:
//! *which exchange graph should carry CODE∘Q traffic, and how does the
//! answer depend on the quantizer?* Method:
//!
//! 1. Compress a representative stochastic dual vector (d = 256K) through
//!    the real wire format for each quantizer (fp32 / uq8 / uq4) — exact
//!    encoded bit counts, not estimates.
//! 2. Sweep K × topology through the per-topology α-β round costs
//!    ([`qgenx::topo::cost`]) at 1 GbE: per-round wire MiB and modeled
//!    wall-clock. Aggregating topologies (ring/star/hierarchical) move
//!    `O(b)` per NIC vs the mesh's `O(K·b)`, so they win once K·b/β
//!    dominates latency — the table shows the crossover at K ≥ 8, and
//!    shows it moving with the quantizer (harder compression → smaller b →
//!    later crossover: CODE∘Q and the graph interact).
//! 3. End-to-end sanity at small scale: run every topology through the
//!    inline coordinator on one problem and report gap / bits / time /
//!    consensus.

use qgenx::benchkit::{fast_mode, scaled, write_csv, Table};
use qgenx::config::{ExperimentConfig, QuantMode, TopoConfig};
use qgenx::coordinator::{run_experiment, Compressor};
use qgenx::net::NetModel;
use qgenx::topo::{build_collective, Collective, Topology};
use qgenx::util::Rng;

const TOPOLOGIES: [&str; 5] = ["full-mesh", "star", "ring", "hierarchical", "gossip"];

/// Exact wire bits for one dual vector under `mode` (real CODE∘Q encode).
fn wire_bits(mode: &str, d: usize) -> u64 {
    let mut quant = qgenx::config::QuantConfig::default();
    quant.mode = QuantMode::parse(mode).unwrap();
    let mut comp = Compressor::from_config(&quant, Rng::seed_from(11)).unwrap();
    let v = Rng::seed_from(12).gaussian_vec(d, 1.0);
    let (_, bits) = comp.compress(&v).unwrap();
    bits
}

fn topo_for(kind: &str, k: usize) -> Topology {
    let mut tc = TopoConfig::default();
    tc.kind = kind.into();
    Topology::from_config(&tc, k).unwrap()
}

fn main() {
    println!("== E10: topology x quantizer trade-off (alpha-beta model, 1 GbE) ==\n");
    let net = NetModel::gbe();
    let d = scaled(262_144, 16_384);

    // ---- part 1+2: real encoded sizes through the per-topology cost model
    let modes = ["fp32", "uq8", "uq4"];
    let bits: Vec<(&str, u64)> = modes.iter().map(|m| (*m, wire_bits(m, d))).collect();
    for (m, b) in &bits {
        println!(
            "payload [{m}]: {:.2} bits/coord, {:.1} KiB encoded",
            *b as f64 / d as f64,
            *b as f64 / 8.0 / 1024.0
        );
    }
    println!();

    let mut csv = Vec::new();
    let mut mesh_beaten_at_8 = true;
    for k in [4usize, 8, 16, 32, 64] {
        let mut table = Table::new(&[
            "K", "mode", "topology", "MiB/round", "sim ms/round", "x vs mesh",
        ]);
        for (mode, b) in &bits {
            let per_rank = vec![*b; k];
            let mesh_cost = build_collective(topo_for("full-mesh", k), k)
                .unwrap()
                .round_cost(&net, &per_rank);
            for kind in TOPOLOGIES {
                let coll = build_collective(topo_for(kind, k), k).unwrap();
                let c = coll.round_cost(&net, &per_rank);
                let speedup = mesh_cost.secs / c.secs;
                let row = vec![
                    k.to_string(),
                    mode.to_string(),
                    kind.to_string(),
                    format!("{:.2}", c.wire_bits as f64 / 8.0 / 1048576.0),
                    format!("{:.3}", c.secs * 1e3),
                    format!("{speedup:.2}"),
                ];
                table.row(&row);
                csv.push(row);
                if k >= 8 && matches!(kind, "star" | "ring" | "hierarchical") {
                    mesh_beaten_at_8 &= c.secs < mesh_cost.secs;
                }
            }
        }
        println!("-- K = {k} --");
        table.print();
        println!();
    }
    write_csv(
        "results/topo_tradeoff_model.csv",
        &["K", "mode", "topology", "mib_per_round", "sim_ms_per_round", "speedup_vs_mesh"],
        &csv,
    )
    .unwrap();
    if fast_mode() {
        // The scaled-down payload is latency-bound (ring pays 2(K−1) α
        // terms), so the crossover claim only holds at full-scale d.
        println!("acceptance check skipped in QGENX_BENCH_FAST mode (payload too small)");
    } else {
        println!(
            "acceptance: ring/star/hierarchical beat full mesh on modeled wall-clock at K >= 8: {}",
            if mesh_beaten_at_8 { "YES" } else { "NO" }
        );
    }

    // ---- part 3: every topology end-to-end through the coordinator
    println!("\n-- end-to-end (inline coordinator, quadratic d=256, K=8, uq4) --");
    let mut table = Table::new(&[
        "topology", "final gap", "total MiB", "sim net secs", "consensus",
    ]);
    let mut csv = Vec::new();
    for kind in TOPOLOGIES {
        let mut cfg = ExperimentConfig::default();
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 256;
        cfg.problem.noise = "absolute".into();
        cfg.problem.sigma = 0.5;
        cfg.workers = 8;
        cfg.iters = scaled(600, 120);
        cfg.eval_every = cfg.iters / 10;
        cfg.seed = 13;
        cfg.topo.kind = kind.into();
        let rec = run_experiment(&cfg).unwrap();
        let consensus = rec
            .scalar("consensus_dist")
            .map(|c| format!("{c:.4}"))
            .unwrap_or_else(|| "exact".into());
        let row = vec![
            kind.to_string(),
            format!("{:.4}", rec.get("gap").unwrap().last().unwrap()),
            format!("{:.2}", rec.scalar("total_bits").unwrap() / 8.0 / 1048576.0),
            format!("{:.4}", rec.scalar("sim_net_time").unwrap()),
            consensus,
        ];
        table.row(&row);
        csv.push(row);
    }
    table.print();
    write_csv(
        "results/topo_tradeoff_e2e.csv",
        &["topology", "final_gap", "total_mib", "sim_net_secs", "consensus"],
        &csv,
    )
    .unwrap();
    println!(
        "\npaper shape: the mesh is latency-optimal at small K*b; aggregation topologies\n\
         win once K*b/beta dominates — and the crossover moves with the quantizer,\n\
         because CODE∘Q shrinks b but not K. Gossip trades exactness (consensus > 0)\n\
         for the lowest per-round cost of all."
    );
}
