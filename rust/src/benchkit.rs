//! Minimal benchmark harness (no `criterion` in the offline image).
//!
//! Each `[[bench]]` target is a `harness = false` binary that uses this
//! module: warmup, fixed repeat count or time budget, median/MAD reporting
//! and an aligned-table printer so bench output reads like the paper's
//! tables. Set `QGENX_BENCH_FAST=1` to shrink workloads for smoke runs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub label: String,
    pub samples: Vec<f64>, // seconds
}

/// Median of an already-sorted slice (empty ⇒ 0.0).
fn median_of_sorted(s: &[f64]) -> f64 {
    let n = s.len();
    if n == 0 {
        return 0.0;
    }
    if n % 2 == 1 {
        s[n / 2]
    } else {
        0.5 * (s[n / 2 - 1] + s[n / 2])
    }
}

impl Timing {
    /// One sorted copy of the samples, shared by [`Self::median`] and
    /// [`Self::mad`] (which used to clone-and-sort independently per
    /// call). `total_cmp` keeps the sort total even if a sample is NaN —
    /// the old `partial_cmp().unwrap()` panicked there.
    fn sorted(&self) -> Vec<f64> {
        let mut s = self.samples.clone();
        s.sort_by(f64::total_cmp);
        s
    }

    pub fn median(&self) -> f64 {
        median_of_sorted(&self.sorted())
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let sorted = self.sorted();
        let m = median_of_sorted(&sorted);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - m).abs()).collect();
        devs.sort_by(f64::total_cmp);
        median_of_sorted(&devs)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }

    /// Fastest sample. Empty timings report 0.0 like the other stats (the
    /// old fold seeded with `f64::INFINITY` leaked `inf` into tables and
    /// JSON, where [`crate::runtime::json::Json::dump`] turns it into
    /// `null`).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().min_by(f64::total_cmp).unwrap_or(0.0)
    }
}

/// Counting global allocator (§Perf, PR 5): every `alloc`/`realloc`/
/// `alloc_zeroed` bumps a process-wide counter, so hot paths can assert
/// "zero allocations in steady state" and telemetry can report allocation
/// deltas per round.
///
/// Rust allows exactly one `#[global_allocator]` per binary, so this
/// module exports the *type* and the counter; each bench or test binary
/// that wants counting installs its own:
///
/// ```ignore
/// #[global_allocator]
/// static GLOBAL: qgenx::benchkit::CountingAlloc = qgenx::benchkit::CountingAlloc;
/// ```
///
/// Binaries that don't install it still link fine — [`allocs`] just stays
/// at 0, which [`crate::telemetry`] treats as "counter not installed".
pub struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Total allocation events since process start (0 unless the binary
/// installed [`CountingAlloc`] as its global allocator).
pub fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Mean allocation events per call of `f` over `calls` invocations.
/// Meaningful only under an installed [`CountingAlloc`].
pub fn allocs_per_call<F: FnMut()>(calls: u64, mut f: F) -> f64 {
    let before = allocs();
    for _ in 0..calls {
        f();
    }
    (allocs() - before) as f64 / calls.max(1) as f64
}

/// True when the fast/smoke mode is requested (CI and `make bench-fast`).
pub fn fast_mode() -> bool {
    std::env::var("QGENX_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Iteration budget for the runnable examples, overridable for CI smoke
/// runs: the `examples-smoke` job sets `QGENX_EXAMPLE_ITERS` to a tiny
/// count so the full example (Session construction, threaded run,
/// assertions, table) executes on every push without the full-length
/// sweep.
pub fn example_iters(default_iters: usize) -> usize {
    std::env::var("QGENX_EXAMPLE_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default_iters)
}

/// Scale an iteration/size parameter down in fast mode.
pub fn scaled(n: usize, fast: usize) -> usize {
    if fast_mode() {
        fast
    } else {
        n
    }
}

/// Read a `usize` knob from the environment (the `QGENX_EXAMPLE_ITERS`
/// pattern, generalized): unset or unparsable values fall back to
/// `default`. The perf harness uses `QGENX_BENCH_DIM` to pin the workload
/// size explicitly (e.g. the CI `perf-smoke` job).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Write a JSON document (creating parent dirs), trailing newline
/// included. Content is [`crate::runtime::json::Json::dump`] — sorted
/// keys, deterministic, re-parsable by the same module. This is how
/// benches emit the machine-readable `BENCH_*.json` trajectory files next
/// to their printed tables.
pub fn write_json(path: &str, doc: &crate::runtime::json::Json) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = doc.dump();
    out.push('\n');
    std::fs::write(path, out)
}

/// Time `f` with `warmup` unmeasured runs then `reps` measured runs.
pub fn bench<F: FnMut()>(label: &str, warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing { label: label.to_string(), samples }
}

/// Time `f` until `budget` elapsed (at least 3 samples).
pub fn bench_for<F: FnMut()>(label: &str, budget: Duration, mut f: F) -> Timing {
    // one warmup
    f();
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    Timing { label: label.to_string(), samples }
}

/// Format seconds with a sensible unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a throughput given bytes processed per call.
pub fn fmt_throughput(bytes: usize, secs: f64) -> String {
    if secs <= 0.0 {
        return "inf".into();
    }
    let bps = bytes as f64 / secs;
    if bps >= 1e9 {
        format!("{:.2} GB/s", bps / 1e9)
    } else if bps >= 1e6 {
        format!("{:.2} MB/s", bps / 1e6)
    } else {
        format!("{:.2} KB/s", bps / 1e3)
    }
}

/// Simple aligned table printer (markdown-ish, like the paper's tables).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Write CSV rows alongside the printed table so EXPERIMENTS.md plots have a
/// machine-readable source. Creates parent dirs.
pub fn write_csv(path: &str, headers: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = String::new();
    out.push_str(&headers.join(","));
    out.push('\n');
    for r in rows {
        out.push_str(&r.join(","));
        out.push('\n');
    }
    std::fs::write(path, out)
}

/// Estimate the slope of log(y) vs log(x) by least squares — used by the
/// rate benches to verify the O(1/sqrt(T)) and O(1/T) exponents.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let pts: Vec<(f64, f64)> = xs
        .iter()
        .zip(ys.iter())
        .filter(|(x, y)| **x > 0.0 && **y > 0.0)
        .map(|(x, y)| (x.ln(), y.ln()))
        .collect();
    let n = pts.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in pts {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_stats() {
        let t = Timing { label: "x".into(), samples: vec![1.0, 2.0, 3.0, 4.0, 100.0] };
        assert_eq!(t.median(), 3.0);
        assert_eq!(t.min(), 1.0);
        assert!(t.mad() <= 2.0); // robust to the outlier
    }

    #[test]
    fn timing_empty_is_all_zeros() {
        // Regression: `min()` used to report `f64::INFINITY` on an empty
        // timing (a bench whose budget admitted zero samples), which JSON
        // output then rendered as null.
        let t = Timing { label: "empty".into(), samples: vec![] };
        assert_eq!(t.min(), 0.0);
        assert_eq!(t.median(), 0.0);
        assert_eq!(t.mad(), 0.0);
        assert_eq!(t.mean(), 0.0);
    }

    #[test]
    fn timing_tolerates_nan_samples() {
        // Regression: `partial_cmp().unwrap()` panicked inside the sort
        // when a sample was NaN (e.g. a derived rate dividing by zero).
        // `total_cmp` orders NaN after every finite value, so the finite
        // half of the distribution still produces sane statistics.
        let t = Timing { label: "nan".into(), samples: vec![2.0, f64::NAN, 1.0] };
        assert_eq!(t.median(), 2.0); // sorted: [1.0, 2.0, NaN]
        assert_eq!(t.min(), 1.0);
        let _ = t.mad(); // must not panic
    }

    #[test]
    fn counting_alloc_counter_is_monotonic() {
        // The test binary does not install CountingAlloc, so the counter
        // just holds still — the telemetry-side contract for "counter not
        // installed" is exactly this monotonic-from-zero behavior.
        let a = allocs();
        let b = allocs();
        assert!(b >= a);
        let per = allocs_per_call(4, || {
            std::hint::black_box(7);
        });
        assert_eq!(per, 0.0);
    }

    #[test]
    fn bench_runs_and_measures() {
        let t = bench("noop", 1, 5, || {
            std::hint::black_box(42);
        });
        assert_eq!(t.samples.len(), 5);
        assert!(t.median() >= 0.0);
    }

    #[test]
    fn loglog_slope_recovers_exponent() {
        let xs: Vec<f64> = (1..=20).map(|i| i as f64 * 10.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x.powf(-0.5)).collect();
        let s = loglog_slope(&xs, &ys);
        assert!((s + 0.5).abs() < 1e-9, "slope={s}");
    }

    #[test]
    fn env_usize_falls_back_on_missing_or_garbage() {
        assert_eq!(env_usize("QGENX_TEST_KNOB_THAT_IS_NEVER_SET", 7), 7);
    }

    #[test]
    fn write_json_emits_reparsable_document() {
        use crate::runtime::json::Json;
        use std::collections::BTreeMap;
        let doc = Json::Obj(BTreeMap::from([
            ("bench".to_string(), Json::Str("x".into())),
            ("n".to_string(), Json::Num(3.0)),
        ]));
        let path = std::env::temp_dir().join("qgenx_benchkit_write_json.json");
        let path = path.to_str().unwrap();
        write_json(path, &doc).unwrap();
        let back = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(back, doc);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_secs(2.0).contains('s'));
        assert!(fmt_secs(2e-3).contains("ms"));
        assert!(fmt_throughput(1_000_000_000, 1.0).contains("GB/s"));
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }
}
