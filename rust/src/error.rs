//! Crate-wide error type.
//!
//! One enum covering every layer so that `qgenx::Result<T>` can flow from
//! the config parser through the coordinator to the PJRT runtime without
//! per-module error plumbing. `Display`/`std::error::Error` are implemented
//! by hand — the offline build image has no `thiserror`.

use std::fmt;

/// Unified error type for the qgenx crate.
#[derive(Debug)]
pub enum Error {
    /// Configuration file could not be parsed or failed validation.
    Config(String),

    /// Wire-format / entropy-coding error (truncated stream, bad symbol...).
    Codec(String),

    /// Quantizer misuse (unsorted levels, empty vector, bad `q`...).
    Quant(String),

    /// Problem / oracle construction error (dimension mismatch etc.).
    Oracle(String),

    /// Coordinator failure (worker panic, lockstep violation...).
    Coordinator(String),

    /// Transport / wire failure (poisoned group, exchange timeout, dead
    /// peer, framing violation...). Carries enough context to tell a local
    /// barrier fault from a socket-level one.
    Net(String),

    /// Topology construction / collective execution error.
    Topology(String),

    /// PJRT runtime failure (missing artifact, compile/execute error).
    Runtime(String),

    /// Artifact manifest missing or malformed.
    Manifest(String),

    /// Generic IO error.
    Io(std::io::Error),

    /// Error bubbled up from the `xla` crate.
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Quant(m) => write!(f, "quantization error: {m}"),
            Error::Oracle(m) => write!(f, "oracle error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Net(m) => write!(f, "net error: {m}"),
            Error::Topology(m) => write!(f, "topology error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_layer() {
        assert_eq!(Error::Config("x".into()).to_string(), "config error: x");
        assert_eq!(Error::Topology("bad graph".into()).to_string(), "topology error: bad graph");
        assert_eq!(Error::Net("peer gone".into()).to_string(), "net error: peer gone");
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
