//! The steppable run API: one [`Session`] behind every runner family.
//!
//! A `Session` is a validated, resumable state machine for one Q-GenX (or
//! QSGDA-baseline) run. Where the seed exposed only run-to-completion
//! functions, a session can be observed mid-flight, stopped early,
//! checkpointed, and embedded as a library:
//!
//! ```no_run
//! use qgenx::config::ExperimentConfig;
//! use qgenx::coordinator::Session;
//!
//! # fn main() -> qgenx::Result<()> {
//! let cfg = ExperimentConfig::default();
//! let mut session = Session::builder(cfg).build()?;
//! while !session.done() {
//!     let report = session.step()?;
//!     if report.evaluated {
//!         println!("t={} gap={:?} bits={}", report.t, report.gap, report.bits_cum);
//!     }
//! }
//! let recorder = session.into_recorder();
//! # let _ = recorder; Ok(())
//! # }
//! ```
//!
//! Internally the session drives one `ExchangePolicy` ([`super::policy`])
//! (exact / gossip / local / SGDA — selected from the config) over the
//! shared [`super::engine::RoundEngine`]. The legacy entry points
//! ([`super::inline::run_experiment`], [`super::threaded::run_threaded`],
//! [`super::inline::run_qsgda_baseline`]) are thin wrappers over this
//! type with bit-identical trajectories and wire bytes (regression-tested
//! against the pre-Session loops in `tests/session_parity.rs`).
//! `docs/API.md` documents the full surface and the migration table.

use super::engine::{Fabric, OracleFactory, RoundEngine};
use super::policy::{ExactPolicy, ExchangePolicy, GossipPolicy, LocalPolicy, SgdaPolicy};
use crate::config::ExperimentConfig;
use crate::error::{Error, Result};
use crate::metrics::Recorder;
use crate::net::Transport;
use crate::oracle::{Oracle, Operator};
use crate::telemetry::{self, Telemetry, TelemetryConfig};
use crate::topo::{build_collective, build_collective_dynamic, Collective, Topology};
use std::sync::Arc;

/// Algorithm driven by the session: the paper's Q-GenX template (exact /
/// gossip / local families per the config) or the QSGDA baseline.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Algorithm {
    #[default]
    QGenX,
    /// QSGDA (Beznosikov et al. 2022), the Figure-4 comparator — an
    /// algorithm policy over the same engine, accounted full-mesh.
    Sgda,
}

/// Observer verdict after each step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Control {
    Continue,
    /// Stop the run: the session finalizes its summary scalars over the
    /// partial trajectory and refuses further steps.
    Stop,
}

/// Streaming hook into a running session. Installed via
/// [`SessionBuilder::observer`]; called after **every** iteration with the
/// per-iteration [`StepReport`] (metric fields are `Some` only on eval
/// steps), and once at finalization with the completed [`Recorder`].
///
/// Early stop: return [`Control::Stop`] to end the run after the current
/// iteration — traffic accounting and summary scalars stay consistent
/// with the truncated trajectory. In a transport-fabric group (threaded
/// execution) a stop decision must be replicated deterministically on
/// every rank, or the peers deadlock at the next barrier; gap-threshold
/// observers belong on loopback sessions (rank 0 is the only rank that
/// sees the gap).
pub trait Observer: Send {
    fn on_step(&mut self, report: &StepReport) -> Control {
        let _ = report;
        Control::Continue
    }

    /// Called once when the session finalizes (run completed or stopped).
    fn on_finish(&mut self, rec: &Recorder) {
        let _ = rec;
    }
}

/// Convenience observer: stop once an eval step's gap falls below a
/// threshold. (Loopback sessions; see the [`Observer`] docs.)
pub struct StopAtGap(pub f64);

impl Observer for StopAtGap {
    fn on_step(&mut self, report: &StepReport) -> Control {
        match report.gap {
            Some(g) if g <= self.0 => Control::Stop,
            _ => Control::Continue,
        }
    }
}

/// Per-iteration report returned by [`Session::step`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StepReport {
    /// Iteration just completed (1-based).
    pub t: usize,
    /// Configured total iterations.
    pub iters: usize,
    /// Adaptive step-size γ after this iteration.
    pub gamma: f64,
    /// Wire bits this iteration added (data + stat rounds).
    pub round_bits: u64,
    /// Cumulative wire bits.
    pub bits_cum: u64,
    /// Synchronous rounds completed so far.
    pub rounds: u64,
    /// Did a pooled stat exchange (level update) fire this iteration?
    pub level_update: bool,
    /// Local family: did this iteration end with a delta sync?
    pub synced: bool,
    /// Was this an eval step (gap/dist/... computed)?
    pub evaluated: bool,
    /// Restricted gap at the evaluation point (eval steps, metrics rank).
    pub gap: Option<f64>,
    /// Distance to the gap ball's center (eval steps, metrics rank).
    pub dist: Option<f64>,
    /// Operator residual at the evaluation point (eval steps, loopback).
    pub residual: Option<f64>,
    /// Consensus distance across replicas (gossip/local eval steps).
    pub consensus: Option<f64>,
    /// `true` once the configured final iteration has completed.
    pub done: bool,
    /// `true` when an observer stopped the run at this step.
    pub stopped: bool,
    /// The closed telemetry record for this step (`None` when telemetry
    /// is off — the default). See [`crate::telemetry`].
    pub telemetry: Option<crate::telemetry::StepRecord>,
}

/// A deep copy of a paused session's full state — algorithm iterates,
/// compressor levels/codecs/RNGs, oracle noise streams, traffic and
/// recorder — from which [`Session::resume`] continues **bit-for-bit**
/// (deterministic series and wire accounting; measured wall-clock times
/// are exempt).
///
/// Loopback checkpoints capture the whole `K`-worker run in one object.
/// A transport rank's checkpoint captures *that rank's* shard of the
/// global state; [`Session::checkpoint`] first runs a rank-coordinated
/// out-of-band barrier so the `K` per-rank checkpoints taken at the same
/// iteration form one consistent global snapshot. Rebind such a shard to
/// a fresh group with [`Session::resume_with_transport`] — the elastic
/// worker-restart primitive.
pub struct Checkpoint {
    cfg: ExperimentConfig,
    eng: RoundEngine,
    policy: Box<dyn ExchangePolicy>,
    rec: Recorder,
    t: usize,
    finalized: bool,
    stopped: bool,
}

impl Checkpoint {
    /// Completed iterations at the moment of capture — all ranks of a
    /// coordinated group checkpoint share this value.
    pub fn iteration(&self) -> usize {
        self.t
    }

    /// The transport rank whose state shard this is (`None` for a loopback
    /// checkpoint, which holds the whole group).
    pub fn rank(&self) -> Option<usize> {
        self.eng.transport_rank()
    }
}

/// Builder for [`Session`]: configure once, validate once.
pub struct SessionBuilder {
    cfg: ExperimentConfig,
    algorithm: Algorithm,
    observers: Vec<Box<dyn Observer>>,
    oracle_factory: Option<Box<OracleFactory>>,
    collective: Option<Arc<dyn Collective>>,
    transport: Option<(Arc<dyn Transport>, usize)>,
    telemetry: Option<TelemetryConfig>,
}

impl SessionBuilder {
    /// Select the driven algorithm (default: the config's Q-GenX family).
    pub fn algorithm(mut self, algorithm: Algorithm) -> Self {
        self.algorithm = algorithm;
        self
    }

    /// Install a streaming [`Observer`] (repeatable).
    pub fn observer(mut self, obs: Box<dyn Observer>) -> Self {
        self.observers.push(obs);
        self
    }

    /// Override the per-rank oracle construction (defaults to the config's
    /// noise model with the seed's per-worker seed derivation).
    pub fn oracle<F>(mut self, factory: F) -> Self
    where
        F: Fn(usize, Arc<dyn Operator>, &ExperimentConfig) -> Result<Box<dyn Oracle>>
            + Send
            + Sync
            + 'static,
    {
        self.oracle_factory = Some(Box::new(factory));
        self
    }

    /// Override the exchange collective (defaults to the one built from
    /// the `[topo]` table; the QSGDA baseline defaults to full mesh).
    pub fn collective(mut self, collective: Arc<dyn Collective>) -> Self {
        self.collective = Some(collective);
        self
    }

    /// Attach this session as rank `rank` of a `K`-endpoint [`Transport`]
    /// group: real encoded bytes move through the fabric — the in-process
    /// [`crate::net::AllGather`] barrier (threaded execution;
    /// [`super::threaded::run_threaded`] is the packaged form) or a
    /// [`crate::net::SocketTransport`] endpoint in its own process (the
    /// `qgenx worker` CLI). Every rank of the group must build a session
    /// against the same logical group and step in lockstep.
    pub fn transport(mut self, transport: Arc<dyn Transport>, rank: usize) -> Self {
        self.transport = Some((transport, rank));
        self
    }

    /// Enable run telemetry (stage spans, counters, per-link streams —
    /// [`crate::telemetry`]). Without this call, `build` falls back to the
    /// `QGENX_TELEMETRY` environment knob, so every session consumer
    /// (examples, benches, the CLI) can be instrumented without code
    /// changes; unset (or `0`) means telemetry stays off.
    pub fn telemetry(mut self, cfg: TelemetryConfig) -> Self {
        self.telemetry = Some(cfg);
        self
    }

    /// Validate the configuration and construct the steppable session.
    pub fn build(self) -> Result<Session> {
        let cfg = self.cfg;
        cfg.validate()?;
        if self.algorithm == Algorithm::Sgda && cfg.algo.method != crate::config::Method::QGenX {
            return Err(Error::Coordinator(format!(
                "the QSGDA baseline is its own update rule and ignores [algo]; \
                 drop method = \"{}\"",
                cfg.algo.method.name()
            )));
        }
        if let Some((transport, rank)) = &self.transport {
            if transport.peers() != cfg.workers {
                return Err(Error::Coordinator(format!(
                    "transport group has {} peers but cfg.workers = {}",
                    transport.peers(),
                    cfg.workers
                )));
            }
            if *rank >= cfg.workers {
                return Err(Error::Coordinator(format!("rank {rank} out of range")));
            }
        }
        // The QSGDA baseline ignores `[topo]` (always full-mesh-accounted,
        // as the seed's run_qsgda_baseline was); Q-GenX builds the
        // configured topology.
        let (topo, collective) = match (&self.collective, self.algorithm) {
            (Some(c), _) => (c.topology(), c.clone()),
            (None, Algorithm::Sgda) => {
                (Topology::FullMesh, build_collective(Topology::FullMesh, cfg.workers)?)
            }
            (None, Algorithm::QGenX) => {
                let topo = Topology::from_config(&cfg.topo, cfg.workers)?;
                // `topo.rewire_every > 0` selects the time-varying gossip
                // schedule; 0 (default) is the static collective, unchanged.
                (topo, build_collective_dynamic(topo, cfg.workers, cfg.topo.rewire_every as u64)?)
            }
        };
        let fabric = match self.transport {
            Some((transport, rank)) => Fabric::Transport { transport, rank },
            None => Fabric::Loopback,
        };
        let mut eng = RoundEngine::new(&cfg, fabric, collective, self.oracle_factory.as_deref())?;
        if let Some(mut tcfg) = self.telemetry.or_else(TelemetryConfig::from_env) {
            // One JSONL file, one writer: only the metrics rank (loopback,
            // or rank 0 of a transport group) attaches the sink; other
            // ranks keep their in-memory ring.
            if !eng.is_metrics_rank() {
                tcfg.jsonl = None;
            }
            eng.set_telemetry(Telemetry::new(&tcfg, &telemetry::manifest_event(&cfg))?);
        }
        let policy: Box<dyn ExchangePolicy> = match self.algorithm {
            Algorithm::Sgda => Box::new(SgdaPolicy::new(&cfg, &eng)),
            Algorithm::QGenX => {
                if cfg.local.steps > 1 {
                    Box::new(LocalPolicy::new(&cfg, &eng))
                } else if !topo.is_exact() {
                    Box::new(GossipPolicy::new(&cfg, &eng))
                } else {
                    Box::new(ExactPolicy::new(&cfg, &eng))
                }
            }
        };
        Ok(Session {
            cfg,
            eng,
            policy,
            rec: Recorder::new(),
            observers: self.observers,
            t: 0,
            finalized: false,
            stopped: false,
        })
    }
}

/// A steppable, observable, checkpointable run (see module docs).
pub struct Session {
    cfg: ExperimentConfig,
    eng: RoundEngine,
    policy: Box<dyn ExchangePolicy>,
    rec: Recorder,
    observers: Vec<Box<dyn Observer>>,
    /// Completed iterations.
    t: usize,
    finalized: bool,
    stopped: bool,
}

impl Session {
    /// Start configuring a session.
    pub fn builder(cfg: ExperimentConfig) -> SessionBuilder {
        SessionBuilder {
            cfg,
            algorithm: Algorithm::QGenX,
            observers: Vec::new(),
            oracle_factory: None,
            collective: None,
            transport: None,
            telemetry: None,
        }
    }

    /// Completed iterations.
    pub fn iteration(&self) -> usize {
        self.t
    }

    /// Configured total iterations.
    pub fn iters(&self) -> usize {
        self.cfg.iters
    }

    /// Has the run completed (or been stopped by an observer)?
    pub fn done(&self) -> bool {
        self.stopped || self.t >= self.cfg.iters
    }

    /// The metrics recorded so far.
    pub fn recorder(&self) -> &Recorder {
        &self.rec
    }

    /// The telemetry recorder (a disabled recorder when telemetry is off):
    /// run-total counters, per-stage seconds, and the in-memory ring of
    /// recent [`crate::telemetry::StepRecord`]s.
    pub fn telemetry(&self) -> &Telemetry {
        self.eng.telemetry()
    }

    /// This endpoint's current replica state (the threaded replication
    /// invariant compares these; sync bases for the local family).
    pub fn replica(&self) -> Vec<f32> {
        self.policy.replica()
    }

    /// Advance one iteration of Algorithm 1 (stat round if due, base /
    /// half-step dual exchanges or local segment + delta sync, state
    /// update, eval-step metrics) and report it. Errors once [`Self::done`].
    pub fn step(&mut self) -> Result<StepReport> {
        if self.done() {
            return Err(Error::Coordinator(format!(
                "session already {} at t = {}",
                if self.stopped { "stopped" } else { "completed" },
                self.t
            )));
        }
        let t = self.t + 1;
        let last = t == self.cfg.iters;
        let mut rep = StepReport { t, iters: self.cfg.iters, ..StepReport::default() };
        let bits_before = self.eng.traffic.bits_sent;
        // Advance a time-varying topology's edge schedule (no-op for
        // static collectives) before the iteration's first exchange.
        self.eng.begin_step(t as u64);
        self.policy.step(t, last, &mut self.eng, &mut self.rec, &mut rep)?;
        let eval_now = t % self.cfg.eval_every.max(1) == 0 || last;
        if eval_now {
            self.policy.eval(t, &mut self.eng, &mut self.rec, &mut rep)?;
            rep.evaluated = true;
        }
        self.t = t;
        rep.gamma = self.policy.gamma();
        rep.round_bits = self.eng.traffic.bits_sent - bits_before;
        rep.bits_cum = self.eng.traffic.bits_sent;
        rep.rounds = self.eng.traffic.rounds;
        rep.done = last;
        // Close the telemetry step before observers run, so a streaming
        // observer (e.g. `telemetry::TelemetryObserver`) sees this step's
        // record on the report it is handed.
        rep.telemetry = self.eng.end_telemetry_step(t as u64);
        let mut stop = false;
        for obs in self.observers.iter_mut() {
            if obs.on_step(&rep) == Control::Stop {
                stop = true;
            }
        }
        if stop && !last {
            self.stopped = true;
            rep.stopped = true;
        }
        if last || self.stopped {
            self.finalize()?;
        }
        Ok(rep)
    }

    /// Run until iteration `target` (clamped to the configured total),
    /// the configured end, or an observer stop — whichever comes first.
    /// Returns the last step's report (`None` if no step ran).
    pub fn run_to(&mut self, target: usize) -> Result<Option<StepReport>> {
        let target = target.min(self.cfg.iters);
        let mut last = None;
        while self.t < target && !self.stopped {
            last = Some(self.step()?);
        }
        Ok(last)
    }

    /// Run to completion and return the recorder — the one-shot form the
    /// legacy wrappers use.
    pub fn run(mut self) -> Result<Recorder> {
        self.run_to(self.cfg.iters)?;
        self.finalize()?;
        Ok(self.rec)
    }

    /// Emit the end-of-run summary scalars over the trajectory so far and
    /// notify observers. Idempotent; called automatically at the last
    /// iteration, on an observer stop, and by [`Self::into_recorder`].
    fn finalize(&mut self) -> Result<()> {
        if self.finalized {
            return Ok(());
        }
        self.policy.finish(&mut self.eng, &mut self.rec)?;
        self.eng.finish_telemetry();
        self.finalized = true;
        for obs in self.observers.iter_mut() {
            obs.on_finish(&self.rec);
        }
        Ok(())
    }

    /// Consume the session, finalizing if needed, and yield the recorder.
    pub fn into_recorder(mut self) -> Recorder {
        // Finalization over a partial run only emits summary scalars; it
        // cannot fail in practice (no wire rounds), but keep the recorder
        // usable either way.
        let _ = self.finalize();
        self.rec
    }

    /// Deep-copy the full run state for a later bit-for-bit [`Self::resume`]
    /// (observers are not captured — re-attach them on the resumed
    /// session).
    ///
    /// On a transport rank this first runs the out-of-band checkpoint
    /// barrier ([`super::engine::RoundEngine::checkpoint_barrier`]): every
    /// rank of the group must call `checkpoint()` at the **same completed
    /// iteration**, and the call fails if any peer is at a different step
    /// (or the fabric is poisoned). The returned per-rank checkpoints are
    /// then one consistent global snapshot; resume each of them onto a
    /// fresh group with [`Self::resume_with_transport`].
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        self.eng.checkpoint_barrier(self.t as u64)?;
        Ok(Checkpoint {
            cfg: self.cfg.clone(),
            eng: self.eng.clone(),
            policy: self.policy.clone_box(),
            rec: self.rec.clone(),
            t: self.t,
            finalized: self.finalized,
            stopped: self.stopped,
        })
    }

    /// Rebuild a session from a [`Checkpoint`]; the continuation matches an
    /// uninterrupted run bit-for-bit on every deterministic series and on
    /// the wire accounting. A transport-rank checkpoint resumed this way
    /// keeps its original transport handle — use
    /// [`Self::resume_with_transport`] after a group restart.
    pub fn resume(cp: Checkpoint) -> Session {
        Session {
            cfg: cp.cfg,
            eng: cp.eng,
            policy: cp.policy,
            rec: cp.rec,
            observers: Vec::new(),
            t: cp.t,
            finalized: cp.finalized,
            stopped: cp.stopped,
        }
    }

    /// Rebuild a transport rank's session from its [`Checkpoint`], attached
    /// to a **fresh** transport group — the elastic restart primitive:
    /// kill a worker (its peers' rounds poison instead of hanging),
    /// rebuild the `K`-endpoint group, and resume every rank from the last
    /// coordinated checkpoint. The continuation is bit-for-bit identical
    /// to the uninterrupted run. The checkpoint holds one rank's state
    /// shard, so `rank` must equal [`Checkpoint::rank`] and the new group
    /// must have the same `K`; loopback checkpoints are refused (use
    /// [`Self::resume`]).
    pub fn resume_with_transport(
        mut cp: Checkpoint,
        transport: Arc<dyn Transport>,
        rank: usize,
    ) -> Result<Session> {
        cp.eng.rebind_transport(transport, rank)?;
        Ok(Session::resume(cp))
    }

    /// Attach an observer to a running (e.g. freshly resumed) session.
    pub fn observe(&mut self, obs: Box<dyn Observer>) {
        self.observers.push(obs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::inline::run_experiment;
    use crate::coordinator::threaded::run_threaded;
    use crate::net::AllGather;

    fn base_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 3;
        cfg.iters = 200;
        cfg.eval_every = 50;
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 12;
        cfg.problem.noise = "absolute".into();
        cfg.problem.sigma = 0.3;
        cfg.quant.update_every = 60;
        cfg
    }

    fn family_cfg(family: &str) -> ExperimentConfig {
        let mut cfg = base_cfg();
        match family {
            "exact" => {}
            "gossip" => {
                cfg.workers = 6;
                cfg.topo.kind = "gossip".into();
                cfg.topo.degree = 2;
            }
            "local" => cfg.local.steps = 4,
            other => panic!("unknown family {other}"),
        }
        cfg
    }

    #[test]
    fn stepping_matches_one_shot_run_bit_for_bit() {
        for family in ["exact", "gossip", "local"] {
            let cfg = family_cfg(family);
            let whole = run_experiment(&cfg).unwrap();
            let mut session = Session::builder(cfg).build().unwrap();
            while !session.done() {
                session.step().unwrap();
            }
            let stepped = session.into_recorder();
            assert_eq!(
                whole.get("gap").unwrap().ys(),
                stepped.get("gap").unwrap().ys(),
                "{family}: stepped trajectory must match the one-shot run"
            );
            assert_eq!(whole.scalar("total_bits"), stepped.scalar("total_bits"), "{family}");
            assert_eq!(whole.scalar("rounds"), stepped.scalar("rounds"), "{family}");
        }
    }

    #[test]
    fn step_reports_stream_per_iteration_state() {
        let cfg = base_cfg();
        let mut session = Session::builder(cfg.clone()).build().unwrap();
        let r1 = session.step().unwrap();
        assert_eq!(r1.t, 1);
        assert!(!r1.evaluated && r1.gap.is_none());
        assert!(r1.round_bits > 0 && r1.bits_cum == r1.round_bits);
        assert!(r1.gamma > 0.0);
        let mut evals = 0;
        let mut last = r1;
        while !session.done() {
            last = session.step().unwrap();
            if last.evaluated {
                evals += 1;
                assert!(last.gap.is_some() && last.residual.is_some());
            }
        }
        assert!(last.done);
        assert_eq!(evals, cfg.iters / cfg.eval_every);
        assert_eq!(last.bits_cum, session.recorder().scalar("total_bits").unwrap() as u64);
        // stepping past the end is a contract violation
        assert!(session.step().is_err());
    }

    #[test]
    fn observer_early_stop_truncates_consistently() {
        // Threshold chosen to trip on an early eval step.
        let cfg = base_cfg();
        let full = run_experiment(&cfg).unwrap();
        let first_gap = full.get("gap").unwrap().points[0].1;
        let mut session =
            Session::builder(cfg.clone()).observer(Box::new(StopAtGap(first_gap))).build().unwrap();
        while !session.done() {
            session.step().unwrap();
        }
        assert!(session.iteration() < cfg.iters, "must stop before the end");
        assert_eq!(session.iteration(), cfg.eval_every, "stops at the first eval step");
        let rec = session.into_recorder();
        // Fewer rounds recorded than the full run, and the accounting is
        // consistent: the rounds/bits scalars describe the truncated
        // trajectory exactly.
        assert!(rec.scalar("rounds").unwrap() < full.scalar("rounds").unwrap());
        assert_eq!(rec.get("gap").unwrap().len(), 1);
        assert_eq!(
            rec.scalar("total_bits").unwrap(),
            rec.get("bits_cum").unwrap().last().unwrap(),
            "summary scalars must describe the truncated run"
        );
        // The partial trajectory is a prefix of the full run's.
        assert_eq!(rec.get("gap").unwrap().ys()[0], full.get("gap").unwrap().ys()[0]);
    }

    #[test]
    fn observer_early_stop_works_on_all_three_families() {
        for family in ["exact", "gossip", "local"] {
            let cfg = family_cfg(family);
            let mut session = Session::builder(cfg.clone())
                .observer(Box::new(StopAtGap(f64::INFINITY)))
                .build()
                .unwrap();
            while !session.done() {
                session.step().unwrap();
            }
            assert_eq!(
                session.iteration(),
                cfg.eval_every,
                "{family}: infinite threshold stops at the first eval step"
            );
            let rec = session.into_recorder();
            assert!(rec.scalar("total_bits").unwrap() > 0.0, "{family}");
        }
    }

    #[test]
    fn checkpoint_resume_matches_uninterrupted_run_on_all_families() {
        for family in ["exact", "gossip", "local"] {
            let cfg = family_cfg(family);
            let whole = run_experiment(&cfg).unwrap();

            let mut first = Session::builder(cfg.clone()).build().unwrap();
            first.run_to(cfg.iters / 2).unwrap();
            let cp = first.checkpoint().unwrap();
            drop(first);
            let mut resumed = Session::resume(cp);
            resumed.run_to(cfg.iters).unwrap();
            let rec = resumed.into_recorder();

            for series in ["gap", "dist", "bits_cum"] {
                assert_eq!(
                    whole.get(series).unwrap().ys(),
                    rec.get(series).unwrap().ys(),
                    "{family}/{series}: resumed run must match bit-for-bit"
                );
            }
            if family != "exact" {
                assert_eq!(
                    whole.get("consensus_dist").unwrap().ys(),
                    rec.get("consensus_dist").unwrap().ys(),
                    "{family}: consensus series must match"
                );
            }
            if family == "local" {
                assert_eq!(
                    whole.get("sync_drift").unwrap().ys(),
                    rec.get("sync_drift").unwrap().ys(),
                    "local: sync accounting must match"
                );
                assert_eq!(whole.scalar("syncs"), rec.scalar("syncs"));
            }
            assert_eq!(whole.scalar("total_bits"), rec.scalar("total_bits"), "{family}");
            assert_eq!(whole.scalar("level_updates"), rec.scalar("level_updates"), "{family}");
        }
    }

    #[test]
    fn checkpoint_resume_carries_a_live_prev_half_bit_for_bit() {
        // Both carriers of the previous half-step dual: the OptDA variant
        // (qgenx family) and PEG (single-call method). The checkpoint is
        // taken at an odd mid-run iteration so `prev_half` is live state
        // the snapshot must capture — the default-variant drill above
        // never exercises it.
        for carrier in ["optda", "peg"] {
            let mut cfg = base_cfg();
            match carrier {
                "optda" => cfg.algo.variant = crate::config::Variant::OptimisticDualAveraging,
                _ => cfg.algo.method = crate::config::Method::Peg,
            }
            let whole = run_experiment(&cfg).unwrap();

            let mut first = Session::builder(cfg.clone()).build().unwrap();
            first.run_to(cfg.iters / 2 + 1).unwrap();
            let cp = first.checkpoint().unwrap();
            drop(first);
            let mut resumed = Session::resume(cp);
            resumed.run_to(cfg.iters).unwrap();
            let rec = resumed.into_recorder();

            for series in ["gap", "dist", "bits_cum"] {
                assert_eq!(
                    whole.get(series).unwrap().ys(),
                    rec.get(series).unwrap().ys(),
                    "{carrier}/{series}: resumed run must match bit-for-bit"
                );
            }
            assert_eq!(whole.scalar("total_bits"), rec.scalar("total_bits"), "{carrier}");
            assert_eq!(whole.scalar("rounds"), rec.scalar("rounds"), "{carrier}");
        }
    }

    #[test]
    fn new_methods_run_on_every_family_with_their_cadence() {
        use crate::config::Method;
        for method in [Method::Peg, Method::EgAa] {
            for family in ["exact", "gossip", "local"] {
                let mut cfg = family_cfg(family);
                cfg.algo.method = method;
                let rec = run_experiment(&cfg).unwrap();
                let gap = *rec.get("gap").unwrap().ys().last().unwrap();
                assert!(gap.is_finite() && gap > 0.0, "{method:?}/{family}: gap {gap}");
                // The cadence scalars exist exactly off the default method.
                assert!(
                    rec.scalar("oracle_calls").unwrap() > 0.0,
                    "{method:?}/{family}"
                );
                if family != "local" {
                    let per = rec.scalar("exchanges_per_step").unwrap();
                    let want = if method == Method::Peg { 1.0 } else { 2.0 };
                    assert_eq!(per, want, "{method:?}/{family}");
                }
                if method == Method::EgAa {
                    if family != "local" {
                        assert!(rec.scalar("aa_accepted_steps").is_some(), "{family}");
                    }
                } else {
                    assert!(rec.scalar("aa_accepted_steps").is_none(), "{family}");
                }
            }
        }
        // And the default stays clean: no cadence scalars on qgenx runs.
        let rec = run_experiment(&base_cfg()).unwrap();
        assert!(rec.scalar("oracle_calls").is_none());
        assert!(rec.scalar("exchanges_per_step").is_none());
    }

    #[test]
    fn peg_halves_the_data_plane_against_extragradient() {
        // Same oracle stream, same quantizer: PEG's single exchange per
        // iteration must land strictly below the two-exchange default in
        // both wire bits and data rounds.
        let de = run_experiment(&base_cfg()).unwrap();
        let mut cfg = base_cfg();
        cfg.algo.method = crate::config::Method::Peg;
        let peg = run_experiment(&cfg).unwrap();
        let (b_de, b_peg) =
            (de.scalar("total_bits").unwrap(), peg.scalar("total_bits").unwrap());
        assert!(b_peg < 0.7 * b_de, "PEG bits {b_peg} vs DE {b_de}");
        assert!(peg.scalar("rounds").unwrap() < de.scalar("rounds").unwrap());
        // One oracle call per iteration, per the method's own accounting.
        assert_eq!(peg.scalar("oracle_calls").unwrap(), cfg.iters as f64);
    }

    #[test]
    fn sgda_baseline_rejects_non_default_methods() {
        let mut cfg = base_cfg();
        cfg.algo.method = crate::config::Method::EgAa;
        let err = Session::builder(cfg).algorithm(Algorithm::Sgda).build().unwrap_err();
        assert!(err.to_string().contains("QSGDA"), "{err}");
    }

    #[test]
    fn checkpoint_resume_covers_the_sgda_baseline_too() {
        let cfg = base_cfg();
        let whole = crate::coordinator::inline::run_qsgda_baseline(&cfg).unwrap();
        let mut first = Session::builder(cfg.clone()).algorithm(Algorithm::Sgda).build().unwrap();
        first.run_to(77).unwrap();
        let mut resumed = Session::resume(first.checkpoint().unwrap());
        resumed.run_to(cfg.iters).unwrap();
        let rec = resumed.into_recorder();
        assert_eq!(whole.get("gap").unwrap().ys(), rec.get("gap").unwrap().ys());
        assert_eq!(whole.get("dist_last").unwrap().ys(), rec.get("dist_last").unwrap().ys());
        assert_eq!(whole.scalar("total_bits"), rec.scalar("total_bits"));
    }

    #[test]
    fn transport_group_checkpoint_and_elastic_resume_is_bit_identical() {
        let cfg = base_cfg();
        let k = cfg.workers;
        let whole = run_experiment(&cfg).unwrap();
        let half = cfg.iters / 2;

        // Phase 1: a K-rank in-process transport group runs to the halfway
        // point and takes a coordinated group checkpoint (every rank calls
        // checkpoint() at the same completed iteration).
        let first = AllGather::new(k);
        let cps: Vec<Checkpoint> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..k)
                .map(|rank| {
                    let cfg = cfg.clone();
                    let tr = first.clone();
                    s.spawn(move || {
                        let mut sess =
                            Session::builder(cfg).transport(tr, rank).build().unwrap();
                        sess.run_to(half).unwrap();
                        sess.checkpoint().unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, cp) in cps.iter().enumerate() {
            assert_eq!(cp.rank(), Some(rank));
            assert_eq!(cp.iteration(), half);
        }

        // Phase 2: the original group is gone (workers "died"); a fresh
        // transport group resumes every rank from its checkpoint shard.
        drop(first);
        let fresh = AllGather::new(k);
        let recs: Vec<Recorder> = std::thread::scope(|s| {
            let handles: Vec<_> = cps
                .into_iter()
                .enumerate()
                .map(|(rank, cp)| {
                    let tr = fresh.clone();
                    let iters = cfg.iters;
                    s.spawn(move || {
                        let mut sess = Session::resume_with_transport(cp, tr, rank).unwrap();
                        sess.run_to(iters).unwrap();
                        sess.into_recorder()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(
            whole.get("gap").unwrap().ys(),
            recs[0].get("gap").unwrap().ys(),
            "elastic resume must continue the trajectory bit-for-bit"
        );
        assert_eq!(whole.scalar("total_bits"), recs[0].scalar("total_bits"));
        assert_eq!(whole.scalar("rounds"), recs[0].scalar("rounds"));
    }

    #[test]
    fn resume_with_transport_validates_fabric_rank_and_group_size() {
        let mut cfg = base_cfg();
        cfg.workers = 1; // single-rank group: barriers complete inline
        cfg.iters = 20;
        cfg.eval_every = 10;
        let whole = run_experiment(&cfg).unwrap();

        // Loopback checkpoints have no rank to rebind.
        let lb = Session::builder(cfg.clone()).build().unwrap();
        let cp = lb.checkpoint().unwrap();
        assert!(cp.rank().is_none());
        let err = Session::resume_with_transport(cp, AllGather::new(1), 0)
            .expect_err("loopback checkpoint must not rebind");
        assert!(err.to_string().contains("loopback"), "got: {err}");

        // A rank's checkpoint resumes only as that rank, in a same-K group.
        let mut s =
            Session::builder(cfg.clone()).transport(AllGather::new(1), 0).build().unwrap();
        s.run_to(5).unwrap();
        let cp = s.checkpoint().unwrap();
        assert_eq!((cp.rank(), cp.iteration()), (Some(0), 5));
        let err = Session::resume_with_transport(cp, AllGather::new(1), 1)
            .expect_err("rank mismatch");
        assert!(err.to_string().contains("cannot resume as rank"), "got: {err}");
        let cp = s.checkpoint().unwrap();
        let err = Session::resume_with_transport(cp, AllGather::new(2), 0)
            .expect_err("group-size mismatch");
        assert!(err.to_string().contains("transport group"), "got: {err}");

        // The happy path continues bit-for-bit on a fresh group.
        let cp = s.checkpoint().unwrap();
        drop(s);
        let mut resumed = Session::resume_with_transport(cp, AllGather::new(1), 0).unwrap();
        resumed.run_to(cfg.iters).unwrap();
        let rec = resumed.into_recorder();
        assert_eq!(whole.get("gap").unwrap().ys(), rec.get("gap").unwrap().ys());
        assert_eq!(whole.scalar("total_bits"), rec.scalar("total_bits"));
    }

    #[test]
    fn resume_with_transport_rejects_a_different_fabric_kind() {
        use crate::net::Plane;

        /// An [`AllGather`] masquerading as a socket fabric: same group
        /// semantics, different `kind()` — the cross-fabric resume probe.
        struct SocketFaced(Arc<AllGather>);
        impl Transport for SocketFaced {
            fn peers(&self) -> usize {
                self.0.peers()
            }
            fn exchange(
                &self,
                rank: usize,
                payload: Vec<u8>,
                plane: Plane,
            ) -> Result<Vec<Arc<Vec<u8>>>> {
                self.0.exchange(rank, payload, plane)
            }
            fn poison(&self, reason: &str) {
                self.0.poison(reason)
            }
            fn is_poisoned(&self) -> bool {
                self.0.is_poisoned()
            }
            fn kind(&self) -> &'static str {
                "socket"
            }
        }

        let mut cfg = base_cfg();
        cfg.workers = 1;
        cfg.iters = 20;
        cfg.eval_every = 10;
        let mut s = Session::builder(cfg).transport(AllGather::new(1), 0).build().unwrap();
        s.run_to(5).unwrap();
        let cp = s.checkpoint().unwrap();
        let fake: Arc<dyn Transport> = Arc::new(SocketFaced(AllGather::new(1)));
        let err = Session::resume_with_transport(cp, fake, 0)
            .expect_err("an inproc checkpoint must not resume on a socket fabric");
        assert!(
            err.to_string().contains("`inproc` fabric") && err.to_string().contains("`socket`"),
            "got: {err}"
        );
        // The original session is still usable on its own fabric.
        s.run_to(20).unwrap();
        assert!(s.done());
    }

    #[test]
    fn coordinated_checkpoint_rejects_iteration_marker_mismatch() {
        use super::super::engine::ckpt_marker;
        use crate::net::Plane;

        // Rank 0 checkpoints at t = 0 while "rank 1" (a raw deposit on the
        // out-of-band plane) claims to be checkpointing step 3: the barrier
        // must refuse the inconsistent snapshot on rank 0.
        let mut cfg = base_cfg();
        cfg.workers = 2;
        let tr = AllGather::new(2);
        let sess = Session::builder(cfg).transport(tr.clone(), 0).build().unwrap();
        let peer = tr.clone();
        let h = std::thread::spawn(move || peer.exchange(1, ckpt_marker(1, 2, 3), Plane::Oob));
        let err = sess.checkpoint().expect_err("marker mismatch must fail the barrier");
        assert!(err.to_string().contains("is not checkpointing step 0"), "got: {err}");
        // The impostor's own exchange completed; nothing hangs.
        h.join().unwrap().unwrap();
    }

    #[test]
    fn transport_builder_validates_group_size() {
        let cfg = base_cfg(); // workers = 3
        let transport = AllGather::new(2);
        assert!(Session::builder(cfg.clone()).transport(transport, 0).build().is_err());
        let transport = AllGather::new(3);
        assert!(Session::builder(cfg).transport(transport, 7).build().is_err());
    }

    #[test]
    fn unified_stat_schedule_keeps_inline_and_threaded_round_counts_equal() {
        // The satellite bugfix's cross-coordinator parity contract: an
        // adaptive-config fp32 run must pay the same (zero) stat rounds in
        // both execution modes, and an adaptive quantized run the same
        // positive number.
        for mode_quantized in [false, true] {
            let mut cfg = base_cfg();
            cfg.iters = 150;
            if !mode_quantized {
                cfg.quant.mode = crate::config::QuantMode::Fp32;
            }
            let inline_rec = run_experiment(&cfg).unwrap();
            let threaded = run_threaded(&cfg).unwrap();
            assert_eq!(
                inline_rec.scalar("rounds").unwrap(),
                threaded.recorder.scalar("rounds").unwrap(),
                "quantized={mode_quantized}: stat-round schedules must agree across coordinators"
            );
            assert_eq!(
                inline_rec.scalar("level_updates").unwrap(),
                threaded.recorder.scalar("level_updates").unwrap(),
                "quantized={mode_quantized}"
            );
        }
    }

    #[test]
    fn custom_oracle_factory_is_honored() {
        use crate::oracle::ExactOracle;
        let mut cfg = base_cfg();
        cfg.iters = 40;
        cfg.eval_every = 20;
        // Noise-free oracles through the factory hook: the run becomes
        // variance-free apart from quantization noise.
        let rec = Session::builder(cfg)
            .oracle(|_rank, op, _cfg| Ok(Box::new(ExactOracle::new(op))))
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!(rec.get("gap").unwrap().last().unwrap().is_finite());
    }

    #[test]
    fn run_to_pauses_and_continues_in_place() {
        let cfg = base_cfg();
        let whole = run_experiment(&cfg).unwrap();
        let mut s = Session::builder(cfg.clone()).build().unwrap();
        s.run_to(50).unwrap();
        assert_eq!(s.iteration(), 50);
        assert!(!s.done());
        s.run_to(usize::MAX).unwrap(); // clamped to cfg.iters
        assert!(s.done());
        let rec = s.into_recorder();
        assert_eq!(whole.get("gap").unwrap().ys(), rec.get("gap").unwrap().ys());
    }
}
