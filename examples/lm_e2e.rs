//! END-TO-END VALIDATION DRIVER (DESIGN.md E12): train the tiny-GPT
//! transformer for a few hundred steps on the synthetic structured corpus
//! with distributed quantized gradient exchange across K workers, logging
//! the loss curve — proving that all three layers compose:
//!
//!   Pallas kernel (L1) ──┐
//!   JAX fwd/bwd (L2) ────┴─ AOT HLO text ─ PJRT (runtime) ─ grads
//!        → quantize (quant) → entropy-code (coding) → allgather (net)
//!        → optimizer (train::lm) → loss ↓
//!
//! The recorded run (EXPERIMENTS.md §E2E) uses the `large` preset (~25M
//! params, QGENX_LM_PRESET=large make artifacts); default artifacts are
//! `small` so this example runs out of the box.
//!
//! ```bash
//! make artifacts && cargo run --release --example lm_e2e [steps] [workers]
//! ```

use qgenx::config::{QuantConfig, QuantMode};
use qgenx::net::NetModel;
use qgenx::runtime::{default_artifacts_dir, Runtime};
use qgenx::train::{LmOptimizer, LmTrainConfig, LmTrainer};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let workers: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(3);
    let dir = default_artifacts_dir()
        .ok_or("run `make artifacts` first")?;
    let mut rt = Runtime::open(dir)?;
    let preset = rt.manifest().lm.preset.clone();
    let params = rt.manifest().lm.params;

    let mut quant = QuantConfig::default();
    quant.mode = QuantMode::Quantized { levels: 14 }; // UQ4 + QAda + Huffman

    let cfg = LmTrainConfig {
        optimizer: LmOptimizer::Msgd { momentum_pct: 90 },
        quant,
        workers,
        steps,
        lr: 0.05,
        eval_every: (steps / 20).max(1),
        seed: 3,
    };
    println!(
        "E2E: tiny-GPT preset={preset} ({params} params), K={workers}, {steps} steps, \
         UQ4 adaptive quantization, 1 GbE model\n"
    );
    let mut tr = LmTrainer::new(&mut rt, cfg, NetModel::gbe())?;
    let rec = tr.train()?;

    println!("  step     train-loss");
    for (x, y) in &rec.get("loss").unwrap().points {
        println!("  {x:>6.0}   {y:>9.4}");
    }
    let eval = tr.eval_loss()?;
    let first = rec.get("loss").unwrap().points.first().unwrap().1;
    let last = rec.get("loss").unwrap().last().unwrap();
    println!("\nheld-out loss: {eval:.4}");
    println!(
        "wire traffic: {:.1} MiB quantized (fp32 would be {:.1} MiB — {:.1}x saving)",
        tr.traffic.bits_sent as f64 / 8.0 / 1048576.0,
        fp32_bits(&tr, steps, workers) / 8.0 / 1048576.0,
        fp32_bits(&tr, steps, workers) / tr.traffic.bits_sent as f64,
    );
    println!(
        "time: grads {:.1}s (measured HLO exec), comm {:.3}s (codec measured + α-β model)",
        tr.grad_time, tr.comm_time
    );
    rec.to_csv("results/lm_e2e.csv")?;
    println!("csv -> results/lm_e2e.csv");
    if last >= first {
        return Err(format!("loss did not decrease: {first} -> {last}").into());
    }
    println!("\nE2E OK: loss {first:.3} -> {last:.3} across {steps} steps");
    Ok(())
}

fn fp32_bits(tr: &LmTrainer, steps: usize, workers: usize) -> f64 {
    // one allgather per step, each worker broadcasts to K-1 peers
    32.0 * tr.param_count() as f64 * steps as f64 * (workers * (workers - 1)) as f64
}
