"""AOT path: every entry lowers to non-trivial HLO text, the manifest is
consistent, and the HLO text round-trips through the XLA parser (the exact
property the Rust loader depends on)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


LM = model.LM_PRESETS["small"]
GAN = model.GanConfig()


class TestLowering:
    def test_all_entries_lower_to_hlo_text(self):
        entries = aot.build_entries(LM, GAN)
        assert set(entries) == {
            "lm_step",
            "lm_loss",
            "gan_disc_step",
            "gan_disc_w_step",
            "gan_pen_step",
            "gan_gen_step",
            "gan_sample",
            "quantize",
            "fused_extragrad",
        }
        for name, (fn, specs) in entries.items():
            lowered = jax.jit(fn).lower(*specs)
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), f"{name}: not HLO text"
            assert "ENTRY" in text, f"{name}: no entry computation"
            assert len(text) > 200, f"{name}: suspiciously small ({len(text)})"

    def test_hlo_text_reparses(self):
        # The Rust side round-trips via HloModuleProto::from_text; verify the
        # text is parseable by running it back through a fresh computation.
        entries = aot.build_entries(LM, GAN)
        fn, specs = entries["quantize"]
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        # xla_client exposes no text parser; check structural invariants the
        # 0.5.1 parser requires instead: one ENTRY, balanced braces, and no
        # serialized-proto artifacts.
        assert text.count("ENTRY") == 1
        assert text.count("{") == text.count("}")

    def test_quantize_entry_executes_like_kernel(self):
        # Executing the lowered computation through jax equals calling the
        # kernel directly (the artifact is faithful).
        entries = aot.build_entries(LM, GAN)
        fn, _specs = entries["quantize"]
        rng = np.random.default_rng(0)
        v = rng.normal(size=aot.QUANT_D).astype(np.float32)
        u = rng.random(aot.QUANT_D).astype(np.float32)
        levels = np.linspace(0, 1, aot.QUANT_LEVELS).astype(np.float32)
        norm = np.array([np.linalg.norm(v)], np.float32)
        out = fn(jnp.array(v), jnp.array(levels), jnp.array(u), jnp.array(norm))[0]
        from compile.kernels.ref import ref_quantize

        ref = ref_quantize(v, levels, u, norm[0])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


class TestCliAndManifest:
    @pytest.fixture(scope="class")
    def out_dir(self, tmp_path_factory):
        d = tmp_path_factory.mktemp("artifacts")
        env = dict(os.environ, QGENX_LM_PRESET="small")
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(d),
             "--only", "quantize,gan_sample,lm_loss"],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
        )
        return d

    def test_cli_writes_artifacts_and_manifest(self, out_dir):
        names = os.listdir(out_dir)
        assert "manifest.json" in names
        assert "quantize.hlo.txt" in names
        assert "lm_params_init.f32" in names
        manifest = json.load(open(out_dir / "manifest.json"))
        assert manifest["lm"]["preset"] == "small"
        assert manifest["lm"]["params"] == model.lm_param_count(LM)
        for entry, meta in manifest["entries"].items():
            assert (out_dir / meta["file"]).exists(), entry
            assert meta["inputs"] and meta["outputs"]

    def test_init_params_blob_shape(self, out_dir):
        blob = np.fromfile(out_dir / "lm_params_init.f32", dtype=np.float32)
        assert blob.size == model.lm_param_count(LM)
        assert np.all(np.isfinite(blob))

    def test_manifest_quantize_shapes(self, out_dir):
        manifest = json.load(open(out_dir / "manifest.json"))
        q = manifest["entries"]["quantize"]
        assert q["inputs"][0]["shape"] == [aot.QUANT_D]
        assert q["inputs"][1]["shape"] == [aot.QUANT_LEVELS]
        assert q["outputs"][0]["shape"] == [aot.QUANT_D]


def test_to_hlo_text_is_text_not_proto():
    # Guard against regressions to .serialize() (64-bit-id protos break the
    # xla 0.1.6 crate — see DESIGN.md §5.1).
    fn = lambda x: (x * 2.0,)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert isinstance(text, str)
    assert text.startswith("HloModule")
    _ = xc  # imported to mirror the aot module's dependency surface
