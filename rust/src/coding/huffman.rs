//! Canonical Huffman coding over the quantization-level alphabet.
//!
//! The paper (Appendix K) encodes level indices with a Huffman code built
//! from the symbol probabilities `p_0..p_{s+1}` of Proposition 2, which the
//! QAda machinery estimates from the weighted CDF. Huffman achieves the
//! minimum expected code length among per-symbol prefix codes, within one
//! bit of the source entropy (Cover & Thomas, Thms 5.4.1 & 5.8.1).
//!
//! We build *canonical* codes so that only the code-length vector needs to
//! be shipped to peers when levels are re-optimized (schedule `U`), and
//! decoding can use the fast canonical per-length first-code method.

use super::bitio::{BitReader, BitWriter};
use crate::error::{Error, Result};

/// Maximum codeword length we allow (alphabets here are ≤ a few hundred
/// symbols; 32 is generous and keeps the decoder tables tiny).
pub const MAX_CODE_LEN: u32 = 32;

/// A canonical Huffman code over symbols `0..n`.
#[derive(Clone, Debug)]
pub struct HuffmanCode {
    /// code length (bits) per symbol; 0 = symbol never occurs (not encodable)
    lengths: Vec<u32>,
    /// canonical codeword per symbol, MSB-first value
    codes: Vec<u64>,
    /// decode tables: for each length L, (first_code[L], index into
    /// `symbols_by_code` where codes of length L start)
    first_code: Vec<u64>,
    first_index: Vec<usize>,
    symbols_by_code: Vec<u32>,
}

impl HuffmanCode {
    /// Build from (unnormalized) symbol weights. Zero-weight symbols get
    /// length 0 (unencodable); if fewer than 2 symbols have weight, a
    /// degenerate 1-bit code is produced so the stream is still decodable.
    pub fn from_weights(weights: &[f64]) -> Result<Self> {
        let n = weights.len();
        if n == 0 {
            return Err(Error::Codec("huffman: empty alphabet".into()));
        }
        if weights.iter().any(|w| !w.is_finite() || *w < 0.0) {
            return Err(Error::Codec("huffman: weights must be finite and >= 0".into()));
        }
        let mut lengths = vec![0u32; n];
        let live: Vec<usize> = (0..n).filter(|&i| weights[i] > 0.0).collect();
        match live.len() {
            0 => {
                // Nothing ever occurs; emit a trivial code on symbol 0 so
                // that an (empty) stream round-trips.
                lengths[0] = 1;
            }
            1 => {
                lengths[live[0]] = 1;
            }
            _ => {
                // Package-merge-free plain Huffman via a tiny binary heap of
                // (weight, node). Depth-limited alphabets are small; if a
                // codeword would exceed MAX_CODE_LEN we flatten by weight
                // clamping (practically unreachable with <=2^20 coords).
                #[derive(PartialEq)]
                struct Node {
                    w: f64,
                    // tie-break on creation order to make codes deterministic
                    order: usize,
                    kind: NodeKind,
                }
                #[derive(PartialEq)]
                enum NodeKind {
                    Leaf(usize),
                    Internal(Box<Node>, Box<Node>),
                }
                impl Eq for Node {}
                impl PartialOrd for Node {
                    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
                        Some(self.cmp(other))
                    }
                }
                impl Ord for Node {
                    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
                        // BinaryHeap is a max-heap; invert for min-heap.
                        other
                            .w
                            .partial_cmp(&self.w)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(other.order.cmp(&self.order))
                    }
                }
                let mut heap = std::collections::BinaryHeap::new();
                let mut order = 0usize;
                for &i in &live {
                    heap.push(Node { w: weights[i], order, kind: NodeKind::Leaf(i) });
                    order += 1;
                }
                while heap.len() > 1 {
                    let a = heap.pop().unwrap();
                    let b = heap.pop().unwrap();
                    heap.push(Node {
                        w: a.w + b.w,
                        order,
                        kind: NodeKind::Internal(Box::new(a), Box::new(b)),
                    });
                    order += 1;
                }
                // DFS to assign depths.
                fn walk(node: &Node, depth: u32, lengths: &mut [u32]) {
                    match &node.kind {
                        NodeKind::Leaf(i) => lengths[*i] = depth.max(1),
                        NodeKind::Internal(a, b) => {
                            walk(a, depth + 1, lengths);
                            walk(b, depth + 1, lengths);
                        }
                    }
                }
                let root = heap.pop().unwrap();
                walk(&root, 0, &mut lengths);
                if lengths.iter().any(|&l| l > MAX_CODE_LEN) {
                    return Err(Error::Codec("huffman: code length overflow".into()));
                }
            }
        }
        Self::from_lengths(lengths)
    }

    /// Build the canonical code from a length vector (what peers receive).
    pub fn from_lengths(lengths: Vec<u32>) -> Result<Self> {
        let max_len = lengths.iter().copied().max().unwrap_or(0);
        if max_len == 0 || max_len > MAX_CODE_LEN {
            return Err(Error::Codec(format!("huffman: invalid max length {max_len}")));
        }
        // Kraft check: sum 2^-l <= 1.
        let kraft: f64 = lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        if kraft > 1.0 + 1e-9 {
            return Err(Error::Codec(format!("huffman: Kraft inequality violated ({kraft})")));
        }
        // Canonical assignment: sort symbols by (length, symbol).
        let mut symbols: Vec<u32> =
            (0..lengths.len() as u32).filter(|&i| lengths[i as usize] > 0).collect();
        symbols.sort_by_key(|&s| (lengths[s as usize], s));

        // Per-length canonical tables:
        //   first_code[l] = (first_code[l-1] + count[l-1]) << 1
        let mut count = vec![0u64; (max_len + 2) as usize];
        for &l in &lengths {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        let mut fc = vec![0u64; (max_len + 2) as usize];
        let mut fi = vec![0usize; (max_len + 2) as usize];
        let mut c = 0u64;
        let mut idx = 0usize;
        for l in 1..=max_len as usize {
            c = (c + if l > 1 { count[l - 1] } else { 0 }) << 1;
            fc[l] = c;
            fi[l] = idx;
            idx += count[l] as usize;
        }
        // Sentinel so the decoder can compute per-length counts by
        // difference for l == max_len.
        fi[max_len as usize + 1] = idx;
        // Derive per-symbol codes from the canonical table.
        let mut next = fc.clone();
        let mut codes = vec![0u64; lengths.len()];
        for &s in &symbols {
            let l = lengths[s as usize] as usize;
            codes[s as usize] = next[l];
            next[l] += 1;
        }

        Ok(HuffmanCode {
            lengths,
            codes,
            first_code: fc,
            first_index: fi,
            symbols_by_code: symbols,
        })
    }

    pub fn alphabet_size(&self) -> usize {
        self.lengths.len()
    }

    /// Code length of `symbol` in bits (0 = unencodable).
    pub fn len_of(&self, symbol: usize) -> u32 {
        self.lengths[symbol]
    }

    /// The length vector (ship this to peers on level updates).
    pub fn lengths(&self) -> &[u32] {
        &self.lengths
    }

    /// Expected code length under a probability vector.
    pub fn expected_len(&self, probs: &[f64]) -> f64 {
        assert_eq!(probs.len(), self.lengths.len());
        probs
            .iter()
            .zip(self.lengths.iter())
            .map(|(p, &l)| p * l as f64)
            .sum()
    }

    /// Encode one symbol.
    #[inline]
    pub fn encode(&self, w: &mut BitWriter, symbol: usize) -> Result<()> {
        let l = self.lengths[symbol];
        if l == 0 {
            return Err(Error::Codec(format!("huffman: symbol {symbol} has no code")));
        }
        // MSB-first emission of the canonical code.
        let code = self.codes[symbol];
        for i in (0..l).rev() {
            w.write_bit((code >> i) & 1 == 1);
        }
        Ok(())
    }

    /// Decode one symbol (canonical first-code method).
    #[inline]
    pub fn decode(&self, r: &mut BitReader) -> Result<u32> {
        let mut code = 0u64;
        let max_len = self.first_code.len() as u32 - 2;
        for l in 1..=max_len {
            code = (code << 1) | r.read_bit()? as u64;
            let count_l = if (l as usize) + 1 < self.first_index.len() {
                self.first_index[l as usize + 1] - self.first_index[l as usize]
            } else {
                self.symbols_by_code.len() - self.first_index[l as usize]
            };
            if count_l > 0 {
                let fc = self.first_code[l as usize];
                if code >= fc && code < fc + count_l as u64 {
                    let idx = self.first_index[l as usize] + (code - fc) as usize;
                    return Ok(self.symbols_by_code[idx]);
                }
            }
        }
        Err(Error::Codec("huffman: invalid codeword".into()))
    }
}

/// Source entropy in bits of a probability vector (0 log 0 := 0).
pub fn entropy_bits(probs: &[f64]) -> f64 {
    probs
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.log2())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;
    use crate::util::Rng;

    fn roundtrip(code: &HuffmanCode, symbols: &[usize]) {
        let mut w = BitWriter::new();
        for &s in symbols {
            code.encode(&mut w, s).unwrap();
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &s in symbols {
            assert_eq!(code.decode(&mut r).unwrap() as usize, s);
        }
    }

    #[test]
    fn uniform_weights_give_balanced_code() {
        let code = HuffmanCode::from_weights(&[1.0; 4]).unwrap();
        for s in 0..4 {
            assert_eq!(code.len_of(s), 2);
        }
        roundtrip(&code, &[0, 1, 2, 3, 3, 2, 1, 0]);
    }

    #[test]
    fn skewed_weights_give_short_code_to_frequent_symbol() {
        let code = HuffmanCode::from_weights(&[0.85, 0.05, 0.05, 0.05]).unwrap();
        assert_eq!(code.len_of(0), 1);
        assert!(code.len_of(1) >= 2);
        roundtrip(&code, &[0, 0, 0, 1, 2, 3, 0]);
    }

    #[test]
    fn expected_len_within_one_bit_of_entropy() {
        // Cover & Thomas Thm 5.4.1: H <= E[L] < H + 1.
        let probs = [0.5, 0.25, 0.125, 0.0625, 0.0625];
        let code = HuffmanCode::from_weights(&probs).unwrap();
        let h = entropy_bits(&probs);
        let el = code.expected_len(&probs);
        assert!(el >= h - 1e-9, "E[L]={el} H={h}");
        assert!(el < h + 1.0, "E[L]={el} H={h}");
        // This dyadic distribution is exactly codable: E[L] == H.
        assert!((el - h).abs() < 1e-9);
    }

    #[test]
    fn single_symbol_alphabet() {
        let code = HuffmanCode::from_weights(&[3.0, 0.0, 0.0]).unwrap();
        assert_eq!(code.len_of(0), 1);
        roundtrip(&code, &[0, 0, 0]);
    }

    #[test]
    fn zero_weight_symbol_is_unencodable() {
        let code = HuffmanCode::from_weights(&[1.0, 0.0, 1.0]).unwrap();
        let mut w = BitWriter::new();
        assert!(code.encode(&mut w, 1).is_err());
    }

    #[test]
    fn lengths_roundtrip_through_canonical_rebuild() {
        let code = HuffmanCode::from_weights(&[0.4, 0.3, 0.2, 0.1]).unwrap();
        let rebuilt = HuffmanCode::from_lengths(code.lengths().to_vec()).unwrap();
        roundtrip(&rebuilt, &[0, 1, 2, 3, 2, 1, 0]);
        // Same lengths -> same expected length.
        let probs = [0.4, 0.3, 0.2, 0.1];
        assert_eq!(code.expected_len(&probs), rebuilt.expected_len(&probs));
    }

    #[test]
    fn kraft_violation_rejected() {
        assert!(HuffmanCode::from_lengths(vec![1, 1, 1]).is_err());
    }

    #[test]
    fn prop_random_weights_roundtrip_and_optimality() {
        forall("huffman roundtrip", 60, |g| {
            let n = g.usize_in(2, 64);
            let weights: Vec<f64> = (0..n).map(|_| g.f64_in(0.001, 1.0)).collect();
            let code = HuffmanCode::from_weights(&weights).unwrap();
            // Kraft equality for complete Huffman codes.
            let kraft: f64 =
                code.lengths().iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
            assert!(kraft <= 1.0 + 1e-9);
            // roundtrip a random symbol stream distributed by the weights
            let mut rng = Rng::seed_from(g.case as u64 + 1);
            let symbols: Vec<usize> = (0..500).map(|_| rng.categorical(&weights)).collect();
            roundtrip(&code, &symbols);
            // E[L] within 1 bit of entropy
            let total: f64 = weights.iter().sum();
            let probs: Vec<f64> = weights.iter().map(|w| w / total).collect();
            let el = code.expected_len(&probs);
            let h = entropy_bits(&probs);
            assert!(el < h + 1.0 && el >= h - 1e-9, "E[L]={el} H={h}");
        });
    }

    #[test]
    fn entropy_known_values() {
        assert!((entropy_bits(&[0.5, 0.5]) - 1.0).abs() < 1e-12);
        assert!((entropy_bits(&[1.0, 0.0]) - 0.0).abs() < 1e-12);
        assert!((entropy_bits(&[0.25; 4]) - 2.0).abs() < 1e-12);
    }
}
