//! Canonical Huffman coding over the quantization-level alphabet.
//!
//! The paper (Appendix K) encodes level indices with a Huffman code built
//! from the symbol probabilities `p_0..p_{s+1}` of Proposition 2, which the
//! QAda machinery estimates from the weighted CDF. Huffman achieves the
//! minimum expected code length among per-symbol prefix codes, within one
//! bit of the source entropy (Cover & Thomas, Thms 5.4.1 & 5.8.1).
//!
//! We build *canonical* codes so that only the code-length vector needs to
//! be shipped to peers when levels are re-optimized (schedule `U`), and
//! decoding can use the fast canonical per-length first-code method.

use super::bitio::{reverse_low_bits, BitReader, BitWriter};
use crate::error::{Error, Result};

/// Maximum codeword length we allow (alphabets here are ≤ a few hundred
/// symbols; 32 is generous and keeps the decoder tables tiny). Codes that
/// would exceed it are flattened by the Kraft-rebalancing fallback in
/// [`HuffmanCode::from_weights`].
pub const MAX_CODE_LEN: u32 = 32;

/// Width of the one-shot decode LUT: a peek of this many stream bits
/// resolves every codeword of length ≤ `DECODE_LUT_BITS` in one table
/// load. 12 bits ⇒ 4096 entries × 4 bytes = 16 KiB per table — covers
/// essentially every symbol of the gradient-index distributions here
/// (longer codes take the canonical first-code fallback).
const DECODE_LUT_BITS: u32 = 12;

/// A canonical Huffman code over symbols `0..n`.
#[derive(Clone, Debug)]
pub struct HuffmanCode {
    /// code length (bits) per symbol; 0 = symbol never occurs (not encodable)
    lengths: Vec<u32>,
    /// canonical codeword per symbol, MSB-first value
    codes: Vec<u64>,
    /// bit-reversed codeword per symbol: the exact value `write_bits`
    /// emits so encoding is one call, not a per-bit loop
    rev_codes: Vec<u64>,
    /// decode tables: for each length L, (first_code[L], index into
    /// `symbols_by_code` where codes of length L start)
    first_code: Vec<u64>,
    first_index: Vec<usize>,
    symbols_by_code: Vec<u32>,
    /// effective LUT width: `min(max_len, DECODE_LUT_BITS)`
    lut_bits: u32,
    /// one-shot decode LUT indexed by the next `lut_bits` stream bits
    /// (LSB-first): `(symbol << 8) | length`, 0 = no short code here
    lut: Vec<u32>,
}

impl HuffmanCode {
    /// Build from (unnormalized) symbol weights. Zero-weight symbols get
    /// length 0 (unencodable); if fewer than 2 symbols have weight, a
    /// degenerate 1-bit code is produced so the stream is still decodable.
    pub fn from_weights(weights: &[f64]) -> Result<Self> {
        let n = weights.len();
        if n == 0 {
            return Err(Error::Codec("huffman: empty alphabet".into()));
        }
        if weights.iter().any(|w| !w.is_finite() || *w < 0.0) {
            return Err(Error::Codec("huffman: weights must be finite and >= 0".into()));
        }
        let mut lengths = vec![0u32; n];
        let live: Vec<usize> = (0..n).filter(|&i| weights[i] > 0.0).collect();
        match live.len() {
            0 => {
                // Nothing ever occurs; emit a trivial code on symbol 0 so
                // that an (empty) stream round-trips.
                lengths[0] = 1;
            }
            1 => {
                lengths[live[0]] = 1;
            }
            _ => {
                // Package-merge-free plain Huffman via a tiny binary heap of
                // (weight, node). Depth-limited alphabets are small; if a
                // codeword would exceed MAX_CODE_LEN we flatten by weight
                // clamping (practically unreachable with <=2^20 coords).
                #[derive(PartialEq)]
                struct Node {
                    w: f64,
                    // tie-break on creation order to make codes deterministic
                    order: usize,
                    kind: NodeKind,
                }
                #[derive(PartialEq)]
                enum NodeKind {
                    Leaf(usize),
                    Internal(Box<Node>, Box<Node>),
                }
                impl Eq for Node {}
                impl PartialOrd for Node {
                    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
                        Some(self.cmp(other))
                    }
                }
                impl Ord for Node {
                    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
                        // BinaryHeap is a max-heap; invert for min-heap.
                        other
                            .w
                            .partial_cmp(&self.w)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(other.order.cmp(&self.order))
                    }
                }
                let mut heap = std::collections::BinaryHeap::new();
                let mut order = 0usize;
                for &i in &live {
                    heap.push(Node { w: weights[i], order, kind: NodeKind::Leaf(i) });
                    order += 1;
                }
                while heap.len() > 1 {
                    let a = heap.pop().unwrap();
                    let b = heap.pop().unwrap();
                    heap.push(Node {
                        w: a.w + b.w,
                        order,
                        kind: NodeKind::Internal(Box::new(a), Box::new(b)),
                    });
                    order += 1;
                }
                // DFS to assign depths.
                fn walk(node: &Node, depth: u32, lengths: &mut [u32]) {
                    match &node.kind {
                        NodeKind::Leaf(i) => lengths[*i] = depth.max(1),
                        NodeKind::Internal(a, b) => {
                            walk(a, depth + 1, lengths);
                            walk(b, depth + 1, lengths);
                        }
                    }
                }
                let root = heap.pop().unwrap();
                walk(&root, 0, &mut lengths);
                if lengths.iter().any(|&l| l > MAX_CODE_LEN) {
                    // Length-limited fallback: with 256-symbol UQ8 alphabets
                    // and exponentially-decaying probabilities floored at
                    // 1e-9, plain Huffman can exceed MAX_CODE_LEN — this
                    // used to hard-error and kill a run mid-training.
                    limit_lengths(&mut lengths, weights, MAX_CODE_LEN);
                }
            }
        }
        Self::from_lengths(lengths)
    }

    /// Build the canonical code from a length vector (what peers receive).
    pub fn from_lengths(lengths: Vec<u32>) -> Result<Self> {
        let max_len = lengths.iter().copied().max().unwrap_or(0);
        if max_len == 0 || max_len > MAX_CODE_LEN {
            return Err(Error::Codec(format!("huffman: invalid max length {max_len}")));
        }
        // Kraft check: sum 2^-l <= 1.
        let kraft: f64 = lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        if kraft > 1.0 + 1e-9 {
            return Err(Error::Codec(format!("huffman: Kraft inequality violated ({kraft})")));
        }
        // Canonical assignment: sort symbols by (length, symbol).
        let mut symbols: Vec<u32> =
            (0..lengths.len() as u32).filter(|&i| lengths[i as usize] > 0).collect();
        symbols.sort_by_key(|&s| (lengths[s as usize], s));

        // Per-length canonical tables:
        //   first_code[l] = (first_code[l-1] + count[l-1]) << 1
        let mut count = vec![0u64; (max_len + 2) as usize];
        for &l in &lengths {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        let mut fc = vec![0u64; (max_len + 2) as usize];
        let mut fi = vec![0usize; (max_len + 2) as usize];
        let mut c = 0u64;
        let mut idx = 0usize;
        for l in 1..=max_len as usize {
            c = (c + if l > 1 { count[l - 1] } else { 0 }) << 1;
            fc[l] = c;
            fi[l] = idx;
            idx += count[l] as usize;
        }
        // Sentinel so the decoder can compute per-length counts by
        // difference for l == max_len.
        fi[max_len as usize + 1] = idx;
        // Derive per-symbol codes from the canonical table.
        let mut next = fc.clone();
        let mut codes = vec![0u64; lengths.len()];
        for &s in &symbols {
            let l = lengths[s as usize] as usize;
            codes[s as usize] = next[l];
            next[l] += 1;
        }

        // Word-at-a-time tables: per-symbol bit-reversed codewords for the
        // single-call encoder, and the one-shot decode LUT. Every stream
        // position whose low `l` bits equal a codeword's reversal decodes
        // to that symbol, so each short code fills a stride of entries.
        let lut_bits = max_len.min(DECODE_LUT_BITS);
        let mut rev_codes = vec![0u64; lengths.len()];
        let mut lut = vec![0u32; 1usize << lut_bits];
        for &s in &symbols {
            let l = lengths[s as usize];
            let rev = reverse_low_bits(codes[s as usize], l);
            rev_codes[s as usize] = rev;
            if l <= lut_bits {
                let entry = (s << 8) | l;
                let mut idx = rev;
                while idx < (1u64 << lut_bits) {
                    lut[idx as usize] = entry;
                    idx += 1u64 << l;
                }
            }
        }

        Ok(HuffmanCode {
            lengths,
            codes,
            rev_codes,
            first_code: fc,
            first_index: fi,
            symbols_by_code: symbols,
            lut_bits,
            lut,
        })
    }

    pub fn alphabet_size(&self) -> usize {
        self.lengths.len()
    }

    /// Code length of `symbol` in bits (0 = unencodable).
    pub fn len_of(&self, symbol: usize) -> u32 {
        self.lengths[symbol]
    }

    /// The canonical (MSB-first) codeword of `symbol` — diagnostics and the
    /// encode-parity tests' per-bit reference emission.
    pub fn code_of(&self, symbol: usize) -> u64 {
        self.codes[symbol]
    }

    /// The length vector (ship this to peers on level updates).
    pub fn lengths(&self) -> &[u32] {
        &self.lengths
    }

    /// Expected code length under a probability vector.
    pub fn expected_len(&self, probs: &[f64]) -> f64 {
        assert_eq!(probs.len(), self.lengths.len());
        probs
            .iter()
            .zip(self.lengths.iter())
            .map(|(p, &l)| p * l as f64)
            .sum()
    }

    /// The wire emission of `symbol`: its bit-reversed codeword and length,
    /// ready for a single `write_bits` call (the LSB-first writer emits a
    /// value's bit 0 first, which is the codeword's MSB). Errors for
    /// unencodable (length-0) symbols. The wire layer uses this to fuse the
    /// trailing sign bit into the same call.
    #[inline]
    pub fn emission_of(&self, symbol: usize) -> Result<(u64, u32)> {
        let l = self.lengths[symbol];
        if l == 0 {
            return Err(Error::Codec(format!("huffman: symbol {symbol} has no code")));
        }
        Ok((self.rev_codes[symbol], l))
    }

    /// Encode one symbol — MSB-first emission of the canonical code, as a
    /// single multi-bit write (bit-identical to the per-bit loop it
    /// replaced; `tests/encode_parity.rs` pins that).
    #[inline]
    pub fn encode(&self, w: &mut BitWriter, symbol: usize) -> Result<()> {
        let (rev, l) = self.emission_of(symbol)?;
        w.write_bits(rev, l);
        Ok(())
    }

    /// Decode one symbol: peek `DECODE_LUT_BITS` stream bits into the
    /// one-shot LUT; codes longer than the LUT (and reads near the end of
    /// a truncated stream) fall back to [`Self::decode_linear`].
    #[inline]
    pub fn decode(&self, r: &mut BitReader) -> Result<u32> {
        let (peek, avail) = r.peek_bits(self.lut_bits);
        if avail > 0 {
            let entry = self.lut[peek as usize];
            let l = entry & 0xFF;
            // A hit is only valid when the full codeword was actually
            // buffered: with fewer bits the zero-extended peek could alias
            // a short code that the real (truncated) stream does not spell.
            if entry != 0 && l <= avail {
                r.skip_bits(l);
                return Ok(entry >> 8);
            }
        }
        self.decode_linear(r)
    }

    /// The canonical per-length first-code decoder — one bit at a time.
    /// Reference implementation (the seed's decode path, against which the
    /// LUT is property-tested and benchmarked) and the fallback for codes
    /// longer than the LUT width.
    #[inline]
    pub fn decode_linear(&self, r: &mut BitReader) -> Result<u32> {
        let mut code = 0u64;
        let max_len = self.first_code.len() as u32 - 2;
        for l in 1..=max_len {
            code = (code << 1) | r.read_bit()? as u64;
            let count_l = if (l as usize) + 1 < self.first_index.len() {
                self.first_index[l as usize + 1] - self.first_index[l as usize]
            } else {
                self.symbols_by_code.len() - self.first_index[l as usize]
            };
            if count_l > 0 {
                let fc = self.first_code[l as usize];
                if code >= fc && code < fc + count_l as u64 {
                    let idx = self.first_index[l as usize] + (code - fc) as usize;
                    return Ok(self.symbols_by_code[idx]);
                }
            }
        }
        Err(Error::Codec("huffman: invalid codeword".into()))
    }
}

/// Kraft-rebalancing length limiter: clamp every length to `max_len`, then
/// restore the Kraft inequality by deepening the lightest still-clampable
/// symbols (cheapest in expected length), and finally spend any slack
/// shortening the heaviest ones. Deterministic (weight ties break on the
/// smaller symbol index) so replicated workers build identical tables from
/// identical pooled statistics. The result is a valid prefix code within
/// `max_len`; near-optimal rather than optimal, which is fine — this path
/// only runs when plain Huffman overflows `max_len`, i.e. for symbols
/// whose probabilities are ≲ 2^-32 anyway.
fn limit_lengths(lengths: &mut [u32], weights: &[f64], max_len: u32) {
    for l in lengths.iter_mut() {
        *l = (*l).min(max_len);
    }
    // Integer Kraft sum in units of 2^-max_len (max_len ≤ 32, so the live
    // symbol count can never overflow u64).
    let budget = 1u64 << max_len;
    let mut kraft: u64 =
        lengths.iter().filter(|&&l| l > 0).map(|&l| 1u64 << (max_len - l)).sum();
    while kraft > budget {
        let i = (0..lengths.len())
            .filter(|&i| lengths[i] > 0 && lengths[i] < max_len)
            .min_by(|&a, &b| {
                weights[a].partial_cmp(&weights[b]).unwrap().then(a.cmp(&b))
            })
            .expect("overfull Kraft implies a symbol shallower than max_len");
        kraft -= 1u64 << (max_len - lengths[i] - 1);
        lengths[i] += 1;
    }
    loop {
        let candidate = (0..lengths.len())
            .filter(|&i| lengths[i] > 1 && kraft + (1u64 << (max_len - lengths[i])) <= budget)
            .max_by(|&a, &b| {
                weights[a].partial_cmp(&weights[b]).unwrap().then(b.cmp(&a))
            });
        match candidate {
            Some(i) => {
                kraft += 1u64 << (max_len - lengths[i]);
                lengths[i] -= 1;
            }
            None => break,
        }
    }
}

/// Source entropy in bits of a probability vector (0 log 0 := 0).
pub fn entropy_bits(probs: &[f64]) -> f64 {
    probs
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.log2())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;
    use crate::util::Rng;

    fn roundtrip(code: &HuffmanCode, symbols: &[usize]) {
        let mut w = BitWriter::new();
        for &s in symbols {
            code.encode(&mut w, s).unwrap();
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &s in symbols {
            assert_eq!(code.decode(&mut r).unwrap() as usize, s);
        }
    }

    #[test]
    fn uniform_weights_give_balanced_code() {
        let code = HuffmanCode::from_weights(&[1.0; 4]).unwrap();
        for s in 0..4 {
            assert_eq!(code.len_of(s), 2);
        }
        roundtrip(&code, &[0, 1, 2, 3, 3, 2, 1, 0]);
    }

    #[test]
    fn skewed_weights_give_short_code_to_frequent_symbol() {
        let code = HuffmanCode::from_weights(&[0.85, 0.05, 0.05, 0.05]).unwrap();
        assert_eq!(code.len_of(0), 1);
        assert!(code.len_of(1) >= 2);
        roundtrip(&code, &[0, 0, 0, 1, 2, 3, 0]);
    }

    #[test]
    fn expected_len_within_one_bit_of_entropy() {
        // Cover & Thomas Thm 5.4.1: H <= E[L] < H + 1.
        let probs = [0.5, 0.25, 0.125, 0.0625, 0.0625];
        let code = HuffmanCode::from_weights(&probs).unwrap();
        let h = entropy_bits(&probs);
        let el = code.expected_len(&probs);
        assert!(el >= h - 1e-9, "E[L]={el} H={h}");
        assert!(el < h + 1.0, "E[L]={el} H={h}");
        // This dyadic distribution is exactly codable: E[L] == H.
        assert!((el - h).abs() < 1e-9);
    }

    #[test]
    fn single_symbol_alphabet() {
        let code = HuffmanCode::from_weights(&[3.0, 0.0, 0.0]).unwrap();
        assert_eq!(code.len_of(0), 1);
        roundtrip(&code, &[0, 0, 0]);
    }

    #[test]
    fn zero_weight_symbol_is_unencodable() {
        let code = HuffmanCode::from_weights(&[1.0, 0.0, 1.0]).unwrap();
        let mut w = BitWriter::new();
        assert!(code.encode(&mut w, 1).is_err());
    }

    #[test]
    fn lengths_roundtrip_through_canonical_rebuild() {
        let code = HuffmanCode::from_weights(&[0.4, 0.3, 0.2, 0.1]).unwrap();
        let rebuilt = HuffmanCode::from_lengths(code.lengths().to_vec()).unwrap();
        roundtrip(&rebuilt, &[0, 1, 2, 3, 2, 1, 0]);
        // Same lengths -> same expected length.
        let probs = [0.4, 0.3, 0.2, 0.1];
        assert_eq!(code.expected_len(&probs), rebuilt.expected_len(&probs));
    }

    #[test]
    fn kraft_violation_rejected() {
        assert!(HuffmanCode::from_lengths(vec![1, 1, 1]).is_err());
    }

    #[test]
    fn prop_random_weights_roundtrip_and_optimality() {
        forall("huffman roundtrip", 60, |g| {
            let n = g.usize_in(2, 64);
            let weights: Vec<f64> = (0..n).map(|_| g.f64_in(0.001, 1.0)).collect();
            let code = HuffmanCode::from_weights(&weights).unwrap();
            // Kraft equality for complete Huffman codes.
            let kraft: f64 =
                code.lengths().iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
            assert!(kraft <= 1.0 + 1e-9);
            // roundtrip a random symbol stream distributed by the weights
            let mut rng = Rng::seed_from(g.case as u64 + 1);
            let symbols: Vec<usize> = (0..500).map(|_| rng.categorical(&weights)).collect();
            roundtrip(&code, &symbols);
            // E[L] within 1 bit of entropy
            let total: f64 = weights.iter().sum();
            let probs: Vec<f64> = weights.iter().map(|w| w / total).collect();
            let el = code.expected_len(&probs);
            let h = entropy_bits(&probs);
            assert!(el < h + 1.0 && el >= h - 1e-9, "E[L]={el} H={h}");
        });
    }

    #[test]
    fn adversarial_weights_take_length_limited_fallback() {
        // Regression: a 256-symbol UQ8 alphabet with exponentially-decaying
        // probabilities floored at 1e-9 (exactly what `WireCodec::new`
        // produces from a peaked QAda estimate) drives plain Huffman past
        // MAX_CODE_LEN — the old code hard-errored here and killed the run.
        let weights: Vec<f64> = (0..256).map(|i| 0.5f64.powi(i).max(1e-9)).collect();
        let code = HuffmanCode::from_weights(&weights).unwrap();
        let max = code.lengths().iter().copied().max().unwrap();
        assert!(max <= MAX_CODE_LEN, "fallback must respect MAX_CODE_LEN, got {max}");
        let kraft: f64 =
            code.lengths().iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-9, "fallback must keep a valid prefix code ({kraft})");
        // Frequent symbols keep short codes; the whole alphabet round-trips.
        assert_eq!(code.len_of(0), 1);
        let symbols: Vec<usize> = (0..256).chain((0..256).rev()).collect();
        roundtrip(&code, &symbols);
        // The same lengths rebuild canonically (the peer-side path).
        let rebuilt = HuffmanCode::from_lengths(code.lengths().to_vec()).unwrap();
        roundtrip(&rebuilt, &symbols);
    }

    #[test]
    fn prop_limit_lengths_all_decay_rates() {
        // Sweep decay rates and alphabet sizes across the overflow
        // boundary: every resulting code must satisfy Kraft within
        // MAX_CODE_LEN and round-trip.
        forall("length-limited huffman", 40, |g| {
            let n = g.usize_in(2, 300);
            let rate = g.f64_in(0.05, 0.95);
            let floor = *g.choose(&[1e-9, 1e-12, 0.0]);
            let weights: Vec<f64> =
                (0..n).map(|i| rate.powi(i.min(1000) as i32).max(floor)).collect();
            let code = HuffmanCode::from_weights(&weights).unwrap();
            assert!(code.lengths().iter().all(|&l| l <= MAX_CODE_LEN));
            let kraft: f64 = code
                .lengths()
                .iter()
                .filter(|&&l| l > 0)
                .map(|&l| 2f64.powi(-(l as i32)))
                .sum();
            assert!(kraft <= 1.0 + 1e-9);
            let encodable: Vec<usize> =
                (0..n).filter(|&s| code.len_of(s) > 0).collect();
            roundtrip(&code, &encodable);
        });
    }

    #[test]
    fn prop_lut_decode_matches_linear_reference() {
        // The one-shot LUT and the canonical first-code loop are the same
        // decoder: identical symbols, identical bit positions, on streams
        // that mix short (LUT-hit) and long (fallback) codewords.
        forall("huffman lut == linear", 60, |g| {
            let n = g.usize_in(2, 300);
            let rate = g.f64_in(0.3, 0.99);
            let weights: Vec<f64> = (0..n).map(|i| rate.powi(i as i32).max(1e-9)).collect();
            let code = HuffmanCode::from_weights(&weights).unwrap();
            let mut rng = Rng::seed_from(g.case as u64 + 7);
            let symbols: Vec<usize> = (0..400).map(|_| rng.categorical(&weights)).collect();
            let mut w = BitWriter::new();
            for &s in &symbols {
                code.encode(&mut w, s).unwrap();
            }
            let bytes = w.finish();
            let mut fast = BitReader::new(&bytes);
            let mut slow = BitReader::new(&bytes);
            for &s in &symbols {
                assert_eq!(code.decode(&mut fast).unwrap() as usize, s);
                assert_eq!(code.decode_linear(&mut slow).unwrap() as usize, s);
                assert_eq!(fast.bits_read(), slow.bits_read());
            }
        });
    }

    #[test]
    fn truncated_stream_fails_in_both_decoders() {
        let code = HuffmanCode::from_weights(&[0.5, 0.25, 0.125, 0.125]).unwrap();
        let mut w = BitWriter::new();
        for s in [3usize, 3, 3] {
            code.encode(&mut w, s).unwrap();
        }
        let bytes = w.finish();
        // Cut mid-codeword: 3 three-bit codes = 9 bits → 1 byte holds 8.
        let cut = &bytes[..1];
        let mut r = BitReader::new(cut);
        assert_eq!(code.decode(&mut r).unwrap(), 3);
        assert_eq!(code.decode(&mut r).unwrap(), 3);
        assert!(code.decode(&mut r).is_err(), "partial trailing codeword must error");
    }

    #[test]
    fn entropy_known_values() {
        assert!((entropy_bits(&[0.5, 0.5]) - 1.0).abs() < 1e-12);
        assert!((entropy_bits(&[1.0, 0.0]) - 0.0).abs() < 1e-12);
        assert!((entropy_bits(&[0.25; 4]) - 2.0).abs() < 1e-12);
    }
}
