//! LSB-first bit writer/reader over byte buffers.
//!
//! The wire format packs sub-byte fields (sign bits, prefix codes); both
//! codecs and the quantizer wire format share these primitives. LSB-first
//! ordering keeps `write_bits`/`read_bits` branch-light: a 64-bit staging
//! register is flushed a byte at a time.

use crate::error::{Error, Result};

/// Append-only bit sink backed by `Vec<u8>`.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// staging register, LSB-first
    acc: u64,
    /// number of valid bits in `acc`
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), acc: 0, nbits: 0 }
    }

    /// Total bits written so far.
    #[inline]
    pub fn bit_len(&self) -> u64 {
        (self.buf.len() as u64) * 8 + self.nbits as u64
    }

    /// Write the low `n` bits of `value` (n <= 57 to keep the staging
    /// register from overflowing in one call).
    #[inline]
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 57, "write_bits supports at most 57 bits per call");
        debug_assert!(n == 64 || value < (1u64 << n), "value {value} does not fit in {n} bits");
        self.acc |= value << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Write a full u32 (e.g. the f32 norm bits, C_b = 32).
    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        self.write_bits(v as u64 & 0xFFFF_FFFF, 32);
    }

    /// Write an f32 by bit pattern.
    #[inline]
    pub fn write_f32(&mut self, v: f32) {
        self.write_u32(v.to_bits());
    }

    /// Flush and return the byte buffer (final partial byte zero-padded).
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xFF) as u8);
        }
        self.buf
    }
}

/// Bit source over a byte slice (LSB-first, mirror of [`BitWriter`]).
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// next byte index
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, acc: 0, nbits: 0 }
    }

    /// Bits consumed so far.
    pub fn bits_read(&self) -> u64 {
        (self.pos as u64) * 8 - self.nbits as u64
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.buf.len() {
            self.acc |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read `n` bits (n <= 57).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u64> {
        debug_assert!(n <= 57);
        if self.nbits < n {
            self.refill();
            if self.nbits < n {
                return Err(Error::Codec(format!(
                    "bitstream truncated: wanted {n} bits, {} available",
                    self.nbits
                )));
            }
        }
        let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
        let v = self.acc & mask;
        self.acc >>= n;
        self.nbits -= n;
        Ok(v)
    }

    #[inline]
    pub fn read_bit(&mut self) -> Result<bool> {
        Ok(self.read_bits(1)? == 1)
    }

    #[inline]
    pub fn read_u32(&mut self) -> Result<u32> {
        Ok(self.read_bits(32)? as u32)
    }

    #[inline]
    pub fn read_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.read_u32()?))
    }

    /// Peek up to `n` bits without consuming (fewer if the stream ends).
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> (u64, u32) {
        self.refill();
        let avail = self.nbits.min(n);
        let mask = if avail >= 64 { u64::MAX } else { (1u64 << avail) - 1 };
        (self.acc & mask, avail)
    }

    /// Consume `n` bits previously peeked.
    #[inline]
    pub fn skip_bits(&mut self, n: u32) {
        debug_assert!(self.nbits >= n);
        self.acc >>= n;
        self.nbits -= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;

    #[test]
    fn roundtrip_fixed_patterns() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bit(true);
        w.write_u32(0xDEAD_BEEF);
        w.write_f32(3.5);
        w.write_bits(0x7F, 7);
        let bytes = w.finish();

        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert!(r.read_bit().unwrap());
        assert_eq!(r.read_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.read_f32().unwrap(), 3.5);
        assert_eq!(r.read_bits(7).unwrap(), 0x7F);
    }

    #[test]
    fn truncated_stream_errors() {
        let mut w = BitWriter::new();
        w.write_bits(0b11, 2);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        let _ = r.read_bits(2).unwrap();
        // Only padding left; reading 32 bits must fail.
        assert!(r.read_bits(32).is_err());
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write_bits(1, 1);
        assert_eq!(w.bit_len(), 1);
        w.write_u32(7);
        assert_eq!(w.bit_len(), 33);
    }

    #[test]
    fn prop_roundtrip_random_fields() {
        forall("bitio roundtrip", 200, |g| {
            let n_fields = g.usize_in(1, 64);
            let fields: Vec<(u64, u32)> = (0..n_fields)
                .map(|_| {
                    let n = g.usize_in(1, 57) as u32;
                    let v = g.u64_below(1u64 << n.min(63));
                    (v & ((1u64 << n) - 1), n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &fields {
                w.write_bits(v, n);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(v, n) in &fields {
                assert_eq!(r.read_bits(n).unwrap(), v);
            }
        });
    }

    #[test]
    fn peek_then_skip_matches_read() {
        let mut w = BitWriter::new();
        w.write_bits(0b1101_0110, 8);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        let (p, avail) = r.peek_bits(5);
        assert_eq!(avail, 5);
        assert_eq!(p, 0b1_0110);
        r.skip_bits(5);
        assert_eq!(r.read_bits(3).unwrap(), 0b110);
    }
}
