//! E14 — error-feedback contractive compression vs the unbiased bit floor.
//!
//! The unbiased `CODE ∘ Q` stack cannot spend fewer matched-gap bits than
//! the Theorem-2 expected code length allows, no matter the codec. Biased
//! δ-contractive operators (top-k, rank-r) break that floor: they ship a
//! fraction of the coordinates and let the per-worker error memory
//! `e_{t+1} = e_t + g_t − C(e_t + g_t)` repair the bias over time
//! (Beznosikov et al. 2023; Zhang et al. 2023 — PAPERS.md). Method:
//!
//! 1. Three runs per oracle, identical everything except the compressor:
//!    * **uq4-huffman** — the repo's best unbiased operating point
//!      (4-bit uniform levels + Huffman codec), the floor to beat;
//!    * **ef-topk** — `[quant.ef] scheme = "topk"`, `k = d/16` (½ bit per
//!      coordinate before index overhead);
//!    * **ef-rankr** — `scheme = "rankr"`, rank 2 on the auto-shaped
//!      near-square factorisation of the dual.
//! 2. Oracles are the LM/GAN-shaped [`BlockScaledQuadratic`] proxies under
//!    relative noise, exactly as `benches/layerwise_tradeoff.rs`.
//! 3. Matched-gap accounting: the target gap is 1.05 × the worst final
//!    gap across the triple; a run's cost is `bits_cum` at its first eval
//!    point at or below the target.
//!
//! Acceptance (full-scale mode): on `lm-proxy`, EF-top-k and/or rank-r
//! reaches the matched gap with strictly fewer total wire bits than the
//! unbiased uq4/huffman configuration. Contractive runs must also stay
//! non-adaptive (zero level updates) and carry the `ef_*` summary scalars.
//! Emits `results/BENCH_ef.json`.
//!
//! [`BlockScaledQuadratic`]: qgenx::oracle::BlockScaledQuadratic

use qgenx::benchkit::{fast_mode, scaled, write_json, Table};
use qgenx::coding::SymbolCodec;
use qgenx::config::{EfConfig, EfScheme, ExperimentConfig, LevelScheme, QuantMode};
use qgenx::coordinator::run_experiment;
use qgenx::metrics::Recorder;
use qgenx::runtime::json::Json;

struct OracleCase {
    kind: &'static str,
    dim: usize,
}

fn cases() -> Vec<OracleCase> {
    vec![
        OracleCase { kind: "lm-proxy", dim: 1280 },
        OracleCase { kind: "gan-proxy", dim: 1024 },
    ]
}

fn base_cfg(case: &OracleCase, iters: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.problem.kind = case.kind.into();
    cfg.problem.dim = case.dim;
    cfg.problem.noise = "relative".into();
    cfg.problem.rel_c = 0.5;
    cfg.workers = 4;
    cfg.iters = iters;
    cfg.eval_every = (iters / 50).max(1);
    cfg.seed = 17;
    cfg
}

/// The unbiased floor: 4-bit uniform levels + Huffman codec.
fn unbiased_cfg(case: &OracleCase, iters: usize) -> ExperimentConfig {
    let mut cfg = base_cfg(case, iters);
    cfg.name = format!("ef_{}_uq4_huffman", case.kind);
    cfg.quant.mode = QuantMode::parse("uq4").unwrap();
    cfg.quant.scheme = LevelScheme::Uniform;
    cfg.quant.codec = SymbolCodec::Huffman;
    cfg.quant.bucket_size = 128;
    cfg.quant.hist_bins = 128;
    cfg.quant.update_every = 100;
    cfg
}

fn ef_cfg(case: &OracleCase, iters: usize, label: &str, ef: EfConfig) -> ExperimentConfig {
    let mut cfg = base_cfg(case, iters);
    cfg.name = format!("ef_{}_{label}", case.kind);
    cfg.quant.ef = ef;
    cfg
}

/// `bits_cum` at the first eval point whose gap is at or below `target`
/// (identical eval grids across the triple make this a fair match).
fn bits_to_gap(rec: &Recorder, target: f64) -> Option<f64> {
    let gaps = rec.get("gap").unwrap();
    let bits = rec.get("bits_cum").unwrap();
    gaps.points
        .iter()
        .zip(bits.points.iter())
        .find(|((_, g), _)| *g <= target)
        .map(|(_, (_, b))| *b)
}

fn main() {
    println!("== E14: error feedback vs the unbiased bit floor — bits at matched gap ==\n");
    let iters = scaled(1500, 250);
    let mut curves = Vec::new();
    let mut lm_win = false;

    for case in cases() {
        let k = case.dim / 16;
        let runs: Vec<(&str, Recorder)> = vec![
            ("uq4-huffman", run_experiment(&unbiased_cfg(&case, iters)).expect("unbiased run")),
            (
                "ef-topk",
                run_experiment(&ef_cfg(
                    &case,
                    iters,
                    "topk",
                    EfConfig { scheme: EfScheme::TopK, k, ..Default::default() },
                ))
                .expect("ef-topk run"),
            ),
            (
                "ef-rankr",
                run_experiment(&ef_cfg(
                    &case,
                    iters,
                    "rankr",
                    EfConfig { scheme: EfScheme::RankR, rank: 2, ..Default::default() },
                ))
                .expect("ef-rankr run"),
            ),
        ];

        let target = 1.05
            * runs
                .iter()
                .map(|(_, r)| r.get("gap").unwrap().last().unwrap())
                .fold(f64::MIN, f64::max);

        let mut table = Table::new(&["compressor", "final gap", "bits@gap", "x vs unbiased"]);
        let bits_u = bits_to_gap(&runs[0].1, target).expect("unbiased reaches the matched gap");
        let mut configs = Vec::new();
        for (name, rec) in &runs {
            let final_gap = rec.get("gap").unwrap().last().unwrap();
            let bits = bits_to_gap(rec, target).expect("every run reaches its own final gap");
            let total = rec.scalar("total_bits").unwrap();
            if *name != "uq4-huffman" {
                // Contractive pipelines are non-adaptive and carry the EF
                // diagnostics; the unbiased floor must carry neither.
                assert_eq!(rec.scalar("level_updates"), Some(0.0), "{name}: no stat rounds");
                assert!(rec.scalar("ef_err_norm").is_some(), "{name}: ef_err_norm scalar");
                assert!(rec.scalar("ef_delta").is_some(), "{name}: ef_delta scalar");
                if case.kind == "lm-proxy" && bits < bits_u {
                    lm_win = true;
                }
            } else {
                assert!(rec.scalar("ef_err_norm").is_none(), "unbiased runs carry no ef_*");
            }
            table.row(&[
                name.to_string(),
                format!("{final_gap:.4}"),
                format!("{bits:.3e}"),
                format!("{:.2}", bits_u / bits),
            ]);
            let mut fields = vec![
                ("name", Json::Str(name.to_string())),
                ("final_gap", Json::Num(final_gap)),
                ("bits_at_gap", Json::Num(bits)),
                ("total_bits", Json::Num(total)),
            ];
            if let Some(en) = rec.scalar("ef_err_norm") {
                fields.push(("ef_err_norm", Json::Num(en)));
                fields.push(("ef_delta", Json::Num(rec.scalar("ef_delta").unwrap())));
            }
            configs.push(Json::obj(fields));
        }
        println!(
            "-- oracle = {} (d = {}, k = {k}, matched gap {target:.4}, T = {iters}) --",
            case.kind, case.dim
        );
        table.print();
        println!();

        curves.push(Json::obj([
            ("oracle", Json::Str(case.kind.into())),
            ("dim", Json::Num(case.dim as f64)),
            ("target_gap", Json::Num(target)),
            ("configs", Json::Arr(configs)),
        ]));
    }

    let doc = Json::obj([
        ("bench", Json::Str("ef_tradeoff".into())),
        ("schema", Json::Num(1.0)),
        ("mode", Json::Str(if fast_mode() { "fast".into() } else { "full".into() })),
        ("curves", Json::Arr(curves)),
    ]);
    write_json("results/BENCH_ef.json", &doc).unwrap();
    println!("wrote results/BENCH_ef.json");

    if fast_mode() {
        println!("acceptance check skipped in QGENX_BENCH_FAST mode (budget too small)");
    } else {
        println!(
            "acceptance: EF-top-k and/or rank-r reaches the matched gap on lm-proxy\n\
             with strictly fewer total wire bits than unbiased uq4/huffman: {}",
            if lm_win { "YES" } else { "NO" }
        );
        assert!(lm_win, "error feedback must beat the unbiased floor on lm-proxy");
    }
    println!(
        "\npaper shape: an unbiased quantizer pays the Theorem-2 code length on\n\
         every coordinate every round. A δ-contractive operator ships only the\n\
         heavy fraction and banks the remainder in the error memory, whose norm\n\
         stays bounded (‖e‖² ≤ (1−δ)/δ · sup‖g‖²), so the trajectory converges\n\
         on strictly fewer wire bits in the low-bit regime — the Three-Pillars\n\
         trade the variance floor for a bias that feedback repairs."
    );
}
