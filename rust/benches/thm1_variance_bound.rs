//! E7 — Theorem 1 (variance bound): empirical `E‖Q_ℓ(v)−v‖²/‖v‖²` vs the
//! ε_Q closed form, across dimensions, level counts, and norms; compared
//! against the QSGD `O(√d/s)` and NUQSGD `O(2^{-s}√d)` bounds the paper's
//! §4 discussion targets.
//!
//! Expected shape (paper): bound always ≥ empirical; adaptive levels give
//! a far smaller ε_Q than QSGD's bound at equal `s` in the large-d L²
//! regime.

use qgenx::benchkit::{scaled, Table};
use qgenx::quant::{
    dequantize, epsilon_q, nuqsgd_variance_bound, optimize_levels, qsgd_variance_bound, quantize,
    Levels, SufficientStats,
};
use qgenx::util::{dist_sq, norm2_sq, Rng};

fn empirical_eps(levels: &Levels, d: usize, q: u32, trials: usize, rng: &mut Rng) -> f64 {
    let mut acc = 0.0;
    let mut n = 0.0;
    for _ in 0..trials {
        let v = rng.gaussian_vec(d, 1.0);
        let qv = quantize(&v, levels, q, 0, rng).unwrap();
        let back = dequantize(&qv, levels);
        acc += dist_sq(&v, &back) / norm2_sq(&v);
        n += 1.0;
    }
    acc / n
}

fn main() {
    println!("== E7 / Theorem 1: quantization variance — empirical vs bounds ==\n");
    let trials = scaled(30, 5);
    let mut rng = Rng::seed_from(0xE7);

    let mut table = Table::new(&[
        "d", "s", "norm", "scheme", "empirical", "eps_Q (Thm 1)", "QSGD bound", "NUQSGD bound",
    ]);
    let mut rows_csv = Vec::new();

    for &d in &[256usize, 4096, 65536] {
        for &s in &[3usize, 15, 255] {
            for (qname, q) in [("l2", 2u32), ("linf", u32::MAX)] {
                for scheme in ["uniform", "exponential", "adaptive"] {
                    if s == 255 && scheme == "exponential" {
                        continue; // 2^-255 underflows; the paper compares at small s
                    }
                    let levels = match scheme {
                        "uniform" => Levels::uniform(s),
                        "exponential" => Levels::exponential(s),
                        _ => {
                            let mut stats = SufficientStats::new(512, q);
                            for _ in 0..8 {
                                let g = rng.gaussian_vec(d, 1.0);
                                stats.observe(&g);
                            }
                            optimize_levels(&stats, s, None, 8).unwrap()
                        }
                    };
                    let emp = empirical_eps(&levels, d, q, trials, &mut rng);
                    let bound = epsilon_q(&levels, d, q);
                    assert!(
                        emp <= bound * 1.15 + 1e-6,
                        "Theorem 1 violated: emp {emp} > bound {bound} (d={d} s={s} {scheme})"
                    );
                    let row = vec![
                        d.to_string(),
                        s.to_string(),
                        qname.to_string(),
                        scheme.to_string(),
                        format!("{emp:.4}"),
                        format!("{bound:.4}"),
                        format!("{:.4}", qsgd_variance_bound(d, s)),
                        format!("{:.4}", nuqsgd_variance_bound(d, s)),
                    ];
                    table.row(&row);
                    rows_csv.push(row);
                }
            }
        }
    }
    table.print();
    qgenx::benchkit::write_csv(
        "results/thm1_variance.csv",
        &["d", "s", "norm", "scheme", "empirical", "eps_q", "qsgd", "nuqsgd"],
        &rows_csv,
    )
    .unwrap();

    // Headline check from §4: adaptive empirical variance beats the QSGD
    // bound at s=15, large d, L2.
    let d = 65536;
    let s = 15;
    let mut stats = SufficientStats::new(512, 2);
    for _ in 0..8 {
        let g = rng.gaussian_vec(d, 1.0);
        stats.observe(&g);
    }
    let ada = optimize_levels(&stats, s, None, 8).unwrap();
    let e_ada = empirical_eps(&ada, d, 2, trials, &mut rng);
    let qsgd = qsgd_variance_bound(d, s);
    println!(
        "\nheadline: adaptive empirical ε = {e_ada:.3} vs QSGD bound {qsgd:.3} at d={d}, s={s} \
         ({}x smaller)",
        (qsgd / e_ada) as i64
    );
    assert!(e_ada < qsgd, "paper claim failed");
    println!("csv -> results/thm1_variance.csv");
}
