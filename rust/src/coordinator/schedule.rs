//! The level-update schedule `U` (paper §3.1: "Let U denote the set of
//! update steps").
//!
//! Quantization levels `ℓ_j` are re-optimized at iterations `t ∈ U`; the
//! run is thereby partitioned into `J` segments of lengths `T_j`
//! (`Σ T_j = T`), which is exactly how Theorems 3/4 account for the
//! per-segment variance bounds `ε_{Q,j}` and code lengths `N_{Q,j}`.

/// Deterministic update schedule: warmup at `t = warmup`, then every
/// `every` iterations.
#[derive(Clone, Copy, Debug)]
pub struct UpdateSchedule {
    /// First update after this many iterations (lets stats accumulate).
    pub warmup: usize,
    /// Period between updates; 0 disables updates entirely.
    pub every: usize,
}

impl UpdateSchedule {
    pub fn new(warmup: usize, every: usize) -> Self {
        UpdateSchedule { warmup, every }
    }

    /// Never update (fixed-level schemes).
    pub fn never() -> Self {
        UpdateSchedule { warmup: 0, every: 0 }
    }

    /// Is iteration `t` (1-based) an update step?
    pub fn is_update(&self, t: usize) -> bool {
        if self.every == 0 {
            return false;
        }
        t >= self.warmup && (t - self.warmup) % self.every == 0
    }

    /// Segment index `j` (0-based) that iteration `t` falls into.
    pub fn segment_of(&self, t: usize) -> usize {
        if self.every == 0 || t < self.warmup {
            0
        } else {
            (t - self.warmup) / self.every + 1
        }
    }

    /// Number of updates in a `T`-iteration run.
    pub fn updates_in(&self, t_total: usize) -> usize {
        (1..=t_total).filter(|&t| self.is_update(t)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_schedule_never_updates() {
        let s = UpdateSchedule::never();
        assert!((1..1000).all(|t| !s.is_update(t)));
        assert_eq!(s.segment_of(500), 0);
    }

    #[test]
    fn periodic_updates_with_warmup() {
        let s = UpdateSchedule::new(10, 100);
        assert!(!s.is_update(1));
        assert!(s.is_update(10));
        assert!(!s.is_update(11));
        assert!(s.is_update(110));
        assert!(s.is_update(210));
        assert_eq!(s.updates_in(500), 5); // t=10,110,210,310,410
    }

    #[test]
    fn segments_partition_the_run() {
        let s = UpdateSchedule::new(0, 50);
        assert_eq!(s.segment_of(0), 1);
        assert_eq!(s.segment_of(49), 1);
        assert_eq!(s.segment_of(50), 2);
        assert_eq!(s.segment_of(99), 2);
    }
}
