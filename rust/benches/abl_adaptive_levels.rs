//! E10 — §3.3 ablation: QAda adaptive levels vs uniform (QSGD-style) vs
//! exponential (NUQSGD-style) placement at a fixed level budget.
//!
//! Measures (i) realized quantization variance, (ii) wire bits/coordinate
//! under Huffman, (iii) final optimization error at equal T — the three
//! quantities the adaptive scheme is supposed to win on simultaneously.

use qgenx::benchkit::{scaled, Table};
use qgenx::config::{ExperimentConfig, LevelScheme};
use qgenx::coordinator::run_experiment;
use qgenx::quant::{dequantize, optimize_levels, quantize, Levels, SufficientStats};
use qgenx::util::{dist_sq, norm2_sq, Rng};

fn main() {
    println!("== E10 / §3.3 ablation: adaptive vs uniform vs exponential levels ==\n");
    let s = 14; // UQ4 budget
    let d = 16384;
    let trials = scaled(20, 5);
    let mut rng = Rng::seed_from(0xE10);

    // Quantization-variance comparison on realistic (gaussian) vectors.
    let mut stats = SufficientStats::new(512, 2);
    for _ in 0..8 {
        let g = rng.gaussian_vec(d, 1.0);
        stats.observe(&g);
    }
    let schemes: Vec<(&str, Levels)> = vec![
        ("uniform", Levels::uniform(s)),
        ("exponential", Levels::exponential(s)),
        ("adaptive", optimize_levels(&stats, s, None, 8).unwrap()),
    ];
    let mut table = Table::new(&["scheme", "E||Q(v)-v||^2 / ||v||^2", "QAda objective"]);
    let mut variances = Vec::new();
    for (name, levels) in &schemes {
        let mut acc = 0.0;
        for _ in 0..trials {
            let v = rng.gaussian_vec(d, 1.0);
            let qv = quantize(&v, levels, 2, 0, &mut rng).unwrap();
            acc += dist_sq(&v, &dequantize(&qv, levels)) / norm2_sq(&v);
        }
        let emp = acc / trials as f64;
        table.row(&[name.to_string(), format!("{emp:.5}"), format!("{:.6}", stats.objective(levels))]);
        variances.push((name.to_string(), emp));
    }
    table.print();
    let v_uni = variances[0].1;
    let v_ada = variances[2].1;
    println!(
        "\nadaptive variance is {:.1}x below uniform at the same {s}-level budget",
        v_uni / v_ada
    );
    assert!(v_ada < v_uni, "QAda must beat uniform placement");

    // End-to-end: same VI run, only the level scheme differs.
    println!("\n-- end-to-end (quadratic, absolute noise, K=3) --");
    let mut e2e = Table::new(&["scheme", "final dist", "total bits", "bits/coord/round"]);
    let mut csv = Vec::new();
    for scheme in [LevelScheme::Uniform, LevelScheme::Exponential, LevelScheme::Adaptive] {
        let mut cfg = ExperimentConfig::default();
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 128;
        cfg.problem.sigma = 0.5;
        cfg.workers = 3;
        cfg.iters = scaled(2000, 300);
        cfg.eval_every = cfg.iters;
        cfg.quant.scheme = scheme;
        cfg.quant.update_every = 200;
        cfg.seed = 21;
        let rec = run_experiment(&cfg).unwrap();
        let dist = rec.get("dist").unwrap().last().unwrap();
        let bits = rec.scalar("total_bits").unwrap();
        let bpr = rec.scalar("bits_per_round_per_worker").unwrap() / cfg.problem.dim as f64;
        let row = vec![
            scheme.name().to_string(),
            format!("{dist:.5}"),
            format!("{bits:.0}"),
            format!("{bpr:.2}"),
        ];
        e2e.row(&row);
        csv.push(row);
    }
    e2e.print();
    qgenx::benchkit::write_csv(
        "results/abl_adaptive_levels.csv",
        &["scheme", "final_dist", "total_bits", "bits_per_coord_round"],
        &csv,
    )
    .unwrap();
    println!("\ncsv -> results/abl_adaptive_levels.csv");
}
