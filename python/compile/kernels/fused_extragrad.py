"""L1 Pallas kernel: fused Q-GenX iterate update.

One pass over the parameter vector applying the paper's update rule
(given the already-averaged decoded dual vectors):

    x_half = x - gamma_cur * v_base        # extrapolation leg
    y_next = y - v_half                    # dual accumulation
    x_next = gamma_next * y_next           # lazy projection X = gamma Y

Fusing the three avoids two extra HBM round-trips over the model vector —
on a real TPU this is purely bandwidth-bound (arithmetic intensity ~0.75
flop/byte), so fusion is worth exactly the 3x traffic reduction.
Interpret mode on CPU; parity against ``ref.ref_fused_extragrad``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _fused_kernel(gammas_ref, x_ref, y_ref, vb_ref, vh_ref, xh_ref, yn_ref, xn_ref):
    g_cur = gammas_ref[0]
    g_next = gammas_ref[1]
    x = x_ref[...]
    y = y_ref[...]
    x_half = x - g_cur * vb_ref[...]
    y_next = y - vh_ref[...]
    xh_ref[...] = x_half
    yn_ref[...] = y_next
    xn_ref[...] = g_next * y_next


@functools.partial(jax.jit, static_argnames=("block",))
def fused_extragrad(x, y, v_base, v_half, gammas, *, block=BLOCK):
    """Apply one fused Q-GenX update.

    Args:
      x, y: f32[d] current primal / dual iterates (d multiple of block).
      v_base, v_half: f32[d] averaged dual vectors (1/K sums).
      gammas: f32[2] = [gamma_t, gamma_{t+1}].

    Returns:
      (x_half, y_next, x_next), each f32[d].
    """
    d = x.shape[0]
    if d % block != 0:
        raise ValueError(f"d={d} must be a multiple of block={block}")
    grid = (d // block,)
    blk = lambda: pl.BlockSpec((block,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((d,), jnp.float32)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),  # gammas: replicated
            blk(),
            blk(),
            blk(),
            blk(),
        ],
        out_specs=(blk(), blk(), blk()),
        out_shape=(out_shape, out_shape, out_shape),
        interpret=True,
    )(gammas, x, y, v_base, v_half)
