//! Cross-fabric parity: the same run must be bit-identical whether its
//! `K` endpoints live in one engine (loopback), on `K` threads over the
//! in-process [`qgenx::net::AllGather`] barrier, or on `K` socket
//! endpoints speaking the framed wire protocol (`docs/WIRE.md`) — plus
//! the measured-vs-modeled reconciliation and the elastic
//! checkpoint/restart contract the socket fabric adds:
//!
//! * Trajectories (gap series, rounds) agree across all three fabrics on
//!   every exact topology; wire-byte accounting agrees exactly between
//!   the two transport fabrics (both bill whole wire bytes).
//! * Telemetry JSONL summaries report the same modeled per-link totals
//!   for loopback and socket runs, and the socket run's measured framed
//!   data bytes — merged across every endpoint's [`MeasuredWire`] —
//!   reconcile *exactly* with the modeled totals on a full mesh.
//! * Killing one worker poisons its peers' rounds (no hang), and the
//!   group resumes bit-for-bit from a coordinated checkpoint on a fresh
//!   socket group.
//! * A real multi-process run (`qgenx launch` spawning `qgenx worker`
//!   subprocesses) reproduces the loopback CLI run's output.

use qgenx::config::{ExperimentConfig, Method};
use qgenx::coordinator::{run_experiment, run_threaded, Checkpoint, Session};
use qgenx::metrics::Recorder;
use qgenx::net::{connect_group, MeasuredWire, SocketOpts, Transport};
use qgenx::runtime::json::Json;
use qgenx::telemetry::TelemetryConfig;
use std::collections::BTreeMap;
use std::thread;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 3;
    cfg.iters = 120;
    cfg.eval_every = 40;
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 12;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.3;
    cfg.quant.update_every = 60;
    cfg
}

/// A fresh rendezvous address per call site: Unix-domain where available,
/// TCP loopback with an ephemeral port elsewhere.
fn rendezvous_addr(tag: &str) -> String {
    #[cfg(unix)]
    {
        format!(
            "unix:{}/qgenx-parity-{}-{tag}.sock",
            std::env::temp_dir().display(),
            std::process::id()
        )
    }
    #[cfg(not(unix))]
    {
        let _ = tag;
        "127.0.0.1:0".into()
    }
}

/// Drive one full run over a same-process socket group (`K` threads, real
/// framed sockets between them); returns every rank's recorder and every
/// endpoint's measured wire counters.
fn run_socket_group(
    cfg: &ExperimentConfig,
    tag: &str,
    telemetry: Option<&str>,
) -> (Vec<Recorder>, Vec<MeasuredWire>) {
    let addr = rendezvous_addr(tag);
    let group = connect_group(&addr, cfg.workers, SocketOpts::default()).unwrap();
    let recs: Vec<Recorder> = thread::scope(|s| {
        let handles: Vec<_> = group
            .iter()
            .cloned()
            .enumerate()
            .map(|(rank, tr)| {
                let cfg = cfg.clone();
                let tele = telemetry.map(str::to_string);
                s.spawn(move || {
                    let mut b = Session::builder(cfg.clone()).transport(tr, rank);
                    if let Some(spec) = tele {
                        b = b.telemetry(TelemetryConfig::parse(&spec).unwrap());
                    }
                    let mut sess = b.build().unwrap();
                    sess.run_to(cfg.iters).unwrap();
                    sess.into_recorder()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let measured = group.iter().map(|t| t.measured().unwrap()).collect();
    (recs, measured)
}

#[test]
fn socket_fabric_matches_loopback_and_threads_on_exact_topologies() {
    for (i, topo) in ["full-mesh", "star", "ring"].iter().enumerate() {
        let mut c = base_cfg();
        c.topo.kind = topo.to_string();
        let inline_rec = run_experiment(&c).unwrap();
        let threaded = run_threaded(&c).unwrap();
        let (recs, _) = run_socket_group(&c, &format!("topo{i}"), None);
        assert_eq!(
            inline_rec.get("gap").unwrap().ys(),
            threaded.recorder.get("gap").unwrap().ys(),
            "{topo}: threads must reproduce the loopback trajectory"
        );
        assert_eq!(
            inline_rec.get("gap").unwrap().ys(),
            recs[0].get("gap").unwrap().ys(),
            "{topo}: sockets must reproduce the loopback trajectory"
        );
        // Both transport fabrics bill whole wire bytes (loopback bills
        // exact code bits — the seed's split), so threads and sockets
        // must agree on the wire accounting to the bit.
        assert_eq!(
            threaded.recorder.scalar("total_bits"),
            recs[0].scalar("total_bits"),
            "{topo}: AllGather and socket wire bytes must be identical"
        );
        assert_eq!(inline_rec.scalar("rounds"), recs[0].scalar("rounds"), "{topo}");
        assert_eq!(inline_rec.scalar("level_updates"), recs[0].scalar("level_updates"), "{topo}");
    }
}

#[test]
fn new_methods_are_fabric_invariant_on_exact_topologies() {
    // The method-cadence seam must be fabric-blind: Past Extra-Gradient
    // (one exchange per step, live `prev_half` state) and EG-AA (two
    // exchanges plus the safeguarded secant mixing) produce the same
    // trajectory, wire accounting, and cadence scalars whether the
    // endpoints are in-engine, threads, or framed sockets.
    for (i, method) in [Method::Peg, Method::EgAa].into_iter().enumerate() {
        for (j, topo) in ["full-mesh", "ring"].iter().enumerate() {
            let mut c = base_cfg();
            c.topo.kind = topo.to_string();
            c.algo.method = method;
            let name = method.name();
            let inline_rec = run_experiment(&c).unwrap();
            let threaded = run_threaded(&c).unwrap();
            let (recs, _) = run_socket_group(&c, &format!("algo{i}{j}"), None);
            assert_eq!(
                inline_rec.get("gap").unwrap().ys(),
                threaded.recorder.get("gap").unwrap().ys(),
                "{name}/{topo}: threads must reproduce the loopback trajectory"
            );
            assert_eq!(
                inline_rec.get("gap").unwrap().ys(),
                recs[0].get("gap").unwrap().ys(),
                "{name}/{topo}: sockets must reproduce the loopback trajectory"
            );
            assert_eq!(
                threaded.recorder.scalar("total_bits"),
                recs[0].scalar("total_bits"),
                "{name}/{topo}: AllGather and socket wire bytes must be identical"
            );
            // The cadence telemetry rides the same metrics rank on every
            // fabric and must agree: one exchange/step for PEG, two for
            // EG-AA, and the same oracle-call count everywhere.
            for rec in [&inline_rec, &threaded.recorder, &recs[0]] {
                assert_eq!(
                    rec.scalar("exchanges_per_step"),
                    Some(if method == Method::Peg { 1.0 } else { 2.0 }),
                    "{name}/{topo}"
                );
            }
            assert_eq!(
                inline_rec.scalar("oracle_calls"),
                recs[0].scalar("oracle_calls"),
                "{name}/{topo}: oracle accounting must be fabric-invariant"
            );
            assert_eq!(inline_rec.scalar("rounds"), recs[0].scalar("rounds"), "{name}/{topo}");
        }
    }
}

/// Read the last (summary) event of a telemetry JSONL stream.
fn last_summary(path: &str) -> Json {
    let text = std::fs::read_to_string(path).unwrap();
    let line = text.lines().filter(|l| !l.trim().is_empty()).next_back().unwrap();
    let j = Json::parse(line).unwrap();
    assert_eq!(j.get("event").unwrap().as_str(), Some("summary"), "stream must end in summary");
    j
}

/// `[src, dst, bytes]` triples → per-link byte map.
fn links_map(summary: &Json, key: &str) -> BTreeMap<(usize, usize), u64> {
    summary
        .get(key)
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|t| {
            let t = t.as_array().unwrap();
            (
                (t[0].as_usize().unwrap(), t[1].as_usize().unwrap()),
                t[2].as_f64().unwrap() as u64,
            )
        })
        .collect()
}

#[test]
fn measured_wire_bytes_reconcile_with_modeled_link_totals_on_full_mesh() {
    let c = base_cfg();
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let sock_path = format!("{}/qgenx-parity-tele-sock-{pid}.jsonl", dir.display());
    let loop_path = format!("{}/qgenx-parity-tele-loop-{pid}.jsonl", dir.display());
    let _ = std::fs::remove_file(&sock_path);
    let _ = std::fs::remove_file(&loop_path);

    Session::builder(c.clone())
        .telemetry(TelemetryConfig::parse(&loop_path).unwrap())
        .build()
        .unwrap()
        .run()
        .unwrap();
    let (_, measured) = run_socket_group(&c, "tele", Some(&sock_path));

    let loop_summary = last_summary(&loop_path);
    let sock_summary = last_summary(&sock_path);

    // Modeled per-link totals agree across fabrics byte-for-byte: both
    // round the same payloads up to whole wire bytes.
    let modeled = links_map(&sock_summary, "link_totals");
    assert_eq!(links_map(&loop_summary, "link_totals"), modeled);
    assert_eq!(modeled.len(), 3 * 2, "full mesh: every ordered pair carries traffic");

    // The framed bytes each endpoint *counted on its own sockets* union
    // into exactly the modeled per-link matrix — measured == modeled on a
    // physical full mesh (the ISSUE's reconciliation acceptance).
    assert_eq!(MeasuredWire::merge_links(&measured), modeled);

    // The loopback summary has no measured object; the socket summary
    // embeds rank 0's own view with real traffic on every plane.
    assert!(loop_summary.get("measured").is_none());
    assert_eq!(sock_summary.at(&["measured", "rank"]).unwrap().as_usize(), Some(0));
    assert!(sock_summary.at(&["measured", "data_bytes_sent"]).unwrap().as_f64().unwrap() > 0.0);
    assert!(sock_summary.at(&["measured", "header_bytes"]).unwrap().as_f64().unwrap() > 0.0);
    assert!(sock_summary.at(&["measured", "oob_bytes_sent"]).unwrap().as_f64().unwrap() > 0.0);

    let _ = std::fs::remove_file(&sock_path);
    let _ = std::fs::remove_file(&loop_path);
}

#[test]
fn killed_worker_poisons_peers_and_group_resumes_from_coordinated_checkpoint() {
    let c = base_cfg();
    let k = c.workers;
    let half = c.iters / 2;
    let reference = run_threaded(&c).unwrap(); // transport billing, full run

    // Phase 1: run to the halfway point, take a coordinated group
    // checkpoint over the socket's out-of-band plane, then worker 2 dies
    // a few iterations later. Survivors must error out of their next
    // round with the poison reason — never hang.
    let group = connect_group(&rendezvous_addr("ckpt1"), k, SocketOpts::default()).unwrap();
    let cps: Vec<Checkpoint> = thread::scope(|s| {
        let handles: Vec<_> = group
            .iter()
            .cloned()
            .enumerate()
            .map(|(rank, tr)| {
                let c = c.clone();
                s.spawn(move || {
                    let mut sess =
                        Session::builder(c.clone()).transport(tr.clone(), rank).build().unwrap();
                    sess.run_to(half).unwrap();
                    let cp = sess.checkpoint().unwrap();
                    if rank == 2 {
                        sess.step().unwrap();
                        tr.poison("worker 2 killed mid-run");
                    } else {
                        sess.step().unwrap(); // t = half+1 completes on all ranks
                        let err = loop {
                            match sess.step() {
                                Ok(_) => {}
                                Err(e) => break e,
                            }
                        };
                        let msg = err.to_string();
                        assert!(msg.contains("poisoned"), "rank {rank}: {msg}");
                        assert!(msg.contains("worker 2 killed mid-run"), "rank {rank}: {msg}");
                    }
                    cp
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    drop(group);
    for (rank, cp) in cps.iter().enumerate() {
        assert_eq!((cp.rank(), cp.iteration()), (Some(rank), half));
    }

    // Phase 2: a fresh socket group, every rank resumed from its shard of
    // the coordinated snapshot — the continuation matches the
    // uninterrupted run bit-for-bit.
    let fresh = connect_group(&rendezvous_addr("ckpt2"), k, SocketOpts::default()).unwrap();
    let recs: Vec<Recorder> = thread::scope(|s| {
        let handles: Vec<_> = cps
            .into_iter()
            .zip(fresh.iter().cloned())
            .enumerate()
            .map(|(rank, (cp, tr))| {
                let iters = c.iters;
                s.spawn(move || {
                    let mut sess = Session::resume_with_transport(cp, tr, rank).unwrap();
                    sess.run_to(iters).unwrap();
                    sess.into_recorder()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(
        reference.recorder.get("gap").unwrap().ys(),
        recs[0].get("gap").unwrap().ys(),
        "resumed group must continue the trajectory bit-for-bit"
    );
    assert_eq!(reference.recorder.scalar("total_bits"), recs[0].scalar("total_bits"));
    assert_eq!(reference.recorder.scalar("rounds"), recs[0].scalar("rounds"));
}

/// The gap-table rows of a CLI run's stdout (between the table header and
/// the summary scalars).
#[cfg(unix)]
fn gap_table(stdout: &str) -> Vec<String> {
    stdout
        .lines()
        .skip_while(|l| !(l.contains("iter") && l.contains("gap")))
        .skip(1)
        .take_while(|l| l.trim_start().starts_with(|ch: char| ch.is_ascii_digit()))
        .map(str::to_string)
        .collect()
}

#[cfg(unix)]
#[test]
fn multiprocess_launch_reproduces_the_loopback_cli_run() {
    // Real OS processes: `launch` spawns one `worker` subprocess per rank
    // over a Unix-domain socket. fp32 keeps every payload byte-aligned, so
    // even the bit totals match the loopback CLI run exactly and the two
    // stdout reports can be compared textually.
    let exe = env!("CARGO_BIN_EXE_qgenx");
    let args = ["--workers", "4", "--iters", "60", "--mode", "fp32"];
    let run = std::process::Command::new(exe)
        .arg("run")
        .args(args)
        .output()
        .expect("spawn qgenx run");
    assert!(run.status.success(), "stderr: {}", String::from_utf8_lossy(&run.stderr));
    let launch = std::process::Command::new(exe)
        .arg("launch")
        .args(args)
        .output()
        .expect("spawn qgenx launch");
    assert!(launch.status.success(), "stderr: {}", String::from_utf8_lossy(&launch.stderr));

    let run_out = String::from_utf8_lossy(&run.stdout);
    let launch_out = String::from_utf8_lossy(&launch.stdout);
    let run_gaps = gap_table(&run_out);
    assert!(!run_gaps.is_empty(), "run must print a gap table:\n{run_out}");
    assert_eq!(run_gaps, gap_table(&launch_out), "launch:\n{launch_out}");
    for key in ["total_bits", "bits_per_round_per_worker"] {
        let pick = |out: &str| -> Option<String> {
            out.lines().find(|l| l.trim_start().starts_with(&format!("{key} ="))).map(String::from)
        };
        assert!(pick(&run_out).is_some(), "{key} must be in the summary:\n{run_out}");
        assert_eq!(pick(&run_out), pick(&launch_out), "{key} lines must match");
    }
}
