//! Synthetic data generators and evaluation metrics for the train drivers.

use crate::util::Rng;

/// Sample `n` points from the classic ring-of-Gaussians 2D benchmark:
/// `modes` Gaussian blobs of width `sigma` on a circle of radius `radius`.
/// Returns interleaved `[x0, y0, x1, y1, ...]` (row-major (n, 2)).
pub fn ring_of_gaussians(n: usize, modes: usize, radius: f64, sigma: f64, rng: &mut Rng) -> Vec<f32> {
    let mut out = Vec::with_capacity(2 * n);
    for _ in 0..n {
        let m = rng.below(modes as u64) as f64;
        let angle = std::f64::consts::TAU * m / modes as f64;
        let cx = radius * angle.cos();
        let cy = radius * angle.sin();
        out.push((cx + sigma * rng.gaussian()) as f32);
        out.push((cy + sigma * rng.gaussian()) as f32);
    }
    out
}

/// Energy distance between two 2D samples (interleaved layout) — our FID
/// analog: `E‖X−Y‖ − ½E‖X−X'‖ − ½E‖Y−Y'‖ ≥ 0`, zero iff the distributions
/// coincide. O(n·m) pairwise; callers subsample to a few hundred points.
pub fn energy_distance_2d(a: &[f32], b: &[f32]) -> f64 {
    let na = a.len() / 2;
    let nb = b.len() / 2;
    assert!(na > 1 && nb > 1, "need at least 2 points per sample");
    let dist = |p: &[f32], i: usize, q: &[f32], j: usize| -> f64 {
        let dx = p[2 * i] as f64 - q[2 * j] as f64;
        let dy = p[2 * i + 1] as f64 - q[2 * j + 1] as f64;
        (dx * dx + dy * dy).sqrt()
    };
    let mut cross = 0.0;
    for i in 0..na {
        for j in 0..nb {
            cross += dist(a, i, b, j);
        }
    }
    cross /= (na * nb) as f64;
    let mut within_a = 0.0;
    for i in 0..na {
        for j in (i + 1)..na {
            within_a += dist(a, i, a, j);
        }
    }
    within_a = 2.0 * within_a / (na * na) as f64;
    let mut within_b = 0.0;
    for i in 0..nb {
        for j in (i + 1)..nb {
            within_b += dist(b, i, b, j);
        }
    }
    within_b = 2.0 * within_b / (nb * nb) as f64;
    (2.0 * cross - within_a - within_b).max(0.0)
}

/// Structured token stream for the LM: a noisy affine recurrence
/// `t_{i+1} = (a·t_i + c) mod V` with occasional uniform-random resets.
/// Learnable (the model can discover the recurrence) but not trivial.
pub struct TokenStream {
    vocab: usize,
    a: u64,
    c: u64,
    noise: f64,
    rng: Rng,
    state: u64,
}

impl TokenStream {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed);
        let state = rng.below(vocab as u64);
        TokenStream { vocab, a: 5, c: 17, noise: 0.05, rng, state }
    }

    /// Fill a (batch, seq) row-major i32 buffer with fresh sequences.
    pub fn next_batch(&mut self, batch: usize, seq: usize, out: &mut Vec<i32>) {
        out.clear();
        out.reserve(batch * seq);
        for _ in 0..batch {
            // fresh random start per sequence
            self.state = self.rng.below(self.vocab as u64);
            for _ in 0..seq {
                out.push(self.state as i32);
                if self.rng.bernoulli(self.noise) {
                    self.state = self.rng.below(self.vocab as u64);
                } else {
                    self.state = (self.a * self.state + self.c) % self.vocab as u64;
                }
            }
        }
    }

    /// Theoretical floor of the per-token cross-entropy for this source:
    /// H = (1−p)·0 + p·log V plus the reset entropy — approximately
    /// `noise · ln(vocab)` once the recurrence is learned.
    pub fn entropy_floor(&self) -> f64 {
        self.noise * (self.vocab as f64).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_geometry() {
        let mut rng = Rng::seed_from(1);
        let pts = ring_of_gaussians(4000, 8, 2.0, 0.01, &mut rng);
        assert_eq!(pts.len(), 8000);
        let mean_r: f64 = (0..4000)
            .map(|i| {
                let x = pts[2 * i] as f64;
                let y = pts[2 * i + 1] as f64;
                (x * x + y * y).sqrt()
            })
            .sum::<f64>()
            / 4000.0;
        assert!((mean_r - 2.0).abs() < 0.05, "mean radius {mean_r}");
    }

    #[test]
    fn energy_distance_properties() {
        let mut rng = Rng::seed_from(2);
        let a = ring_of_gaussians(300, 8, 2.0, 0.05, &mut rng);
        let a2 = ring_of_gaussians(300, 8, 2.0, 0.05, &mut rng);
        // far-away blob
        let shifted: Vec<f32> = a.iter().map(|&v| v + 10.0).collect();
        let same = energy_distance_2d(&a, &a2);
        let far = energy_distance_2d(&a, &shifted);
        assert!(same < 0.1, "same-dist energy {same}");
        assert!(far > 5.0, "far energy {far}");
        assert!(same < far);
        // symmetry
        let ab = energy_distance_2d(&a, &shifted);
        let ba = energy_distance_2d(&shifted, &a);
        assert!((ab - ba).abs() < 1e-9);
    }

    #[test]
    fn energy_distance_detects_mode_collapse() {
        let mut rng = Rng::seed_from(3);
        let real = ring_of_gaussians(300, 8, 2.0, 0.05, &mut rng);
        // mode collapse: all mass on one mode
        let collapsed = ring_of_gaussians(300, 1, 2.0, 0.05, &mut rng);
        let d = energy_distance_2d(&real, &collapsed);
        assert!(d > 0.5, "collapse should be detected: {d}");
    }

    #[test]
    fn token_stream_is_structured() {
        let mut ts = TokenStream::new(256, 4);
        let mut batch = Vec::new();
        ts.next_batch(4, 64, &mut batch);
        assert_eq!(batch.len(), 256);
        assert!(batch.iter().all(|&t| (0..256).contains(&t)));
        // most transitions follow the affine rule
        let mut hits = 0;
        let mut total = 0;
        for s in 0..4 {
            for i in 0..63 {
                let cur = batch[s * 64 + i] as u64;
                let nxt = batch[s * 64 + i + 1] as u64;
                total += 1;
                if nxt == (5 * cur + 17) % 256 {
                    hits += 1;
                }
            }
        }
        assert!(hits as f64 / total as f64 > 0.9, "structure {hits}/{total}");
    }

    #[test]
    fn token_streams_differ_by_seed() {
        let mut a = TokenStream::new(256, 1);
        let mut b = TokenStream::new(256, 2);
        let mut ba = Vec::new();
        let mut bb = Vec::new();
        a.next_batch(1, 32, &mut ba);
        b.next_batch(1, 32, &mut bb);
        assert_ne!(ba, bb);
    }
}
