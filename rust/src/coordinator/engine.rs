//! The shared round engine behind every runner family.
//!
//! [`RoundEngine`] owns everything Algorithm 1 needs *besides* the iterate
//! math: the per-worker oracles and compression endpoints, the collective,
//! the traffic/link accounting, and the stat-exchange schedule. The
//! `ExchangePolicy` ([`super::policy`]) implementations (exact / gossip /
//! local, plus the SGDA baseline) drive it one primitive at a time:
//!
//! * `RoundEngine::dual_exchange` — sample each owned rank's oracle at a
//!   query point, `CODE ∘ Q` the dual vectors, move the encoded bytes one
//!   round over the collective, decode by sender.
//! * `RoundEngine::vector_exchange` — same round, but for caller-provided
//!   vectors (the local-steps families' model deltas).
//! * `RoundEngine::stat_round` — the control-plane pooled stat exchange
//!   (always full-mesh-accounted; the wire format needs identical codecs
//!   everywhere), with the two schedules the runner families use:
//!   `RoundEngine::maybe_per_step_stat` (schedule `U` with early warmup)
//!   and `RoundEngine::maybe_local_stat` (first sync on/after each due
//!   point).
//!
//! One engine serves both execution modes through the fabric:
//!
//! * `Loopback` — this engine owns **all `K` endpoints** in one thread (the
//!   inline simulation). Payloads never move; every sender is decoded once
//!   with its own endpoint, exactly as the seed runner did.
//! * `Transport` — this engine owns **one rank** of a `K`-endpoint group
//!   and moves real encoded bytes through a [`Transport`] fabric: the
//!   in-process [`crate::net::AllGather`] barrier (threads) or the
//!   multi-process [`crate::net::SocketTransport`] (framed sockets) —
//!   the engine cannot tell them apart, which is the point. Exact
//!   payload-bit accounting differs from loopback by design: a transport
//!   sees whole wire bytes (`8 · len`), the loopback encoder reports exact
//!   code bits — the same split the seed's two coordinators had.
//!
//! The per-step stat schedule is built from **one predicate** —
//! `QuantConfig::adapts() && Compressor::is_quantized()` — for both
//! fabrics. (The seed's threaded coordinator built its schedule from
//! `adapts()` alone and re-gated on `is_quantized()` at every step; the
//! duplicated predicate is the kind of drift that once hid the silent
//! Huffman-refresh no-op, so it now lives here and nowhere else.)
//!
//! Timing semantics: compute (oracle + encode + decode) is *measured*,
//! network time is *modeled* — and the barrier wait of the transport
//! fabric is deliberately excluded from compute. Measured times are
//! wall-clock and therefore not covered by the bit-for-bit reproducibility
//! contract (`gap`/`bits_cum`/... are; `sim_time_cum`/`compute_time` are
//! not).

use super::pipeline::Compressor;
use super::schedule::UpdateSchedule;
use crate::config::ExperimentConfig;
use crate::error::{Error, Result};
use crate::net::{MeasuredWire, NetModel, Plane, PoisonGuard, TrafficStats, Transport};
use crate::oracle::{build_oracle, Operator, Oracle};
use crate::telemetry::{Stage, StepRecord, Telemetry};
use crate::topo::{Collective, LinkTraffic};
use crate::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Per-rank oracle constructor for [`crate::coordinator::SessionBuilder::oracle`]:
/// `(rank, operator, config) -> oracle`. The default factory is
/// [`build_oracle`] with the seed's per-worker seed derivation.
pub type OracleFactory =
    dyn Fn(usize, Arc<dyn Operator>, &ExperimentConfig) -> Result<Box<dyn Oracle>> + Send + Sync;

/// How encoded bytes move between ranks (see module docs).
#[derive(Clone)]
pub(crate) enum Fabric {
    /// All `K` endpoints in-process; decode is a loopback.
    Loopback,
    /// One rank of a `K`-endpoint group over any [`Transport`] fabric
    /// (in-process barrier or multi-process sockets).
    Transport { transport: Arc<dyn Transport>, rank: usize },
}

/// A query-point set for one dual exchange round.
pub(crate) enum Query<'a> {
    /// Every owned rank samples at the same point (exact / SGDA families).
    Shared(&'a [f32]),
    /// Owned rank `i` samples at `points[i]` (gossip: per-replica iterates).
    PerOwned(&'a [Vec<f32>]),
}

/// Pool sufficient statistics across co-located compression endpoints and
/// re-optimize every endpoint from the identical rank-ordered payload
/// list. One full-mesh stat round: the exact body the inline coordinator,
/// the LM trainer and the GAN trainer used to hand-copy. No-op when every
/// payload is empty (non-adapting pipelines — the trainers' schedules can
/// fire regardless of the quant config; the engine's cannot, because its
/// schedule is gated on the adapts predicate and an adapting statistic
/// always serializes its header). Otherwise records the payload bits as
/// allgather traffic, then drives [`Compressor::update_levels`] on every
/// endpoint. Returns whether any endpoint's level placement changed
/// (callers that only care about the side effect can `?;` or `map` it
/// away; the telemetry layer reports it as `level_update`).
pub fn pool_local_stats(
    comps: &mut [Compressor],
    net: &NetModel,
    traffic: &mut TrafficStats,
) -> Result<bool> {
    let payloads: Vec<Vec<u8>> = comps.iter().map(|c| c.stats_payload()).collect();
    if payloads.iter().all(|p| p.is_empty()) {
        return Ok(false);
    }
    let bits: Vec<u64> = payloads.iter().map(|p| 8 * p.len() as u64).collect();
    traffic.record_allgather(&bits, net);
    let rank_order: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
    let mut changed = false;
    for comp in comps.iter_mut() {
        changed |= comp.update_levels(&rank_order)?;
    }
    Ok(changed)
}

/// Out-of-band diagnostic allgather at eval steps (transport fabric):
/// every rank contributes `[X_t ‖ X̄]` through the shared f32 wire helpers
/// ([`crate::net::put_f32s`]) on the out-of-band plane — deliberately NOT
/// billed to traffic; it exists so rank 0 can evaluate cross-replica
/// metrics. Every rank must call it at the same step so the group stays in
/// lockstep. Returns `Some((per-rank iterates, mean ergodic average))` on
/// rank 0.
fn diag_exchange(
    rank: usize,
    k: usize,
    d: usize,
    transport: &dyn Transport,
    x_world: &[f32],
    ergodic: &[f32],
) -> Result<Option<(Vec<Vec<f32>>, Vec<f32>)>> {
    let mut diag = Vec::with_capacity(8 * d);
    crate::net::put_f32s(&mut diag, x_world);
    crate::net::put_f32s(&mut diag, ergodic);
    let got = transport.exchange(rank, diag, Plane::Oob)?;
    if rank != 0 {
        return Ok(None);
    }
    let mut iterates = Vec::with_capacity(k);
    let mut mean_avg = vec![0.0f32; d];
    for p in &got {
        let mut f = vec![0.0f32; 2 * d];
        crate::net::get_f32s_into(p, &mut f)
            .map_err(|e| Error::Coordinator(format!("bad diagnostic payload: {e}")))?;
        iterates.push(f[..d].to_vec());
        for (m, &x) in mean_avg.iter_mut().zip(f[d..].iter()) {
            *m += x / k as f32;
        }
    }
    Ok(Some((iterates, mean_avg)))
}

/// The 20-byte out-of-band checkpoint-barrier marker every rank of a
/// transport group contributes before a coordinated group checkpoint:
/// `b"QCKP" ‖ k u32 ‖ rank u32 ‖ step u64` (little-endian).
pub(crate) fn ckpt_marker(rank: usize, k: usize, t: u64) -> Vec<u8> {
    let mut m = Vec::with_capacity(20);
    m.extend_from_slice(b"QCKP");
    m.extend_from_slice(&(k as u32).to_le_bytes());
    m.extend_from_slice(&(rank as u32).to_le_bytes());
    m.extend_from_slice(&t.to_le_bytes());
    m
}

/// Validate a full set of checkpoint markers: every rank present, same
/// group size, same step. A mismatch means some rank called
/// `checkpoint()` at a different iteration — a programming error that
/// must surface loudly, not silently skew the restart point.
pub(crate) fn check_ckpt_markers(k: usize, t: u64, got: &[Arc<Vec<u8>>]) -> Result<()> {
    if got.len() != k {
        return Err(Error::Net(format!(
            "checkpoint barrier saw {} markers for a group of {k}",
            got.len()
        )));
    }
    for (r, p) in got.iter().enumerate() {
        if p.as_slice() != ckpt_marker(r, k, t).as_slice() {
            return Err(Error::Net(format!(
                "checkpoint barrier mismatch: rank {r} is not checkpointing step {t} \
                 (every rank must call checkpoint() at the same iteration)"
            )));
        }
    }
    Ok(())
}

/// The shared round engine (see module docs). Fields are crate-visible:
/// the policies in [`super::policy`] are its only drivers.
pub struct RoundEngine {
    pub(crate) op: Arc<dyn Operator>,
    pub(crate) d: usize,
    pub(crate) k: usize,
    fabric: Fabric,
    /// Poisons the transport group if this engine's thread panics.
    _guard: Option<PoisonGuard>,
    pub(crate) collective: Arc<dyn Collective>,
    pub(crate) net: NetModel,
    /// Ranks driven locally: `0..K` under loopback, `[rank]` under transport.
    pub(crate) owned: Vec<usize>,
    /// Per-owned-rank closed receive sets (all `K` under exact topologies).
    pub(crate) recv: Vec<Vec<usize>>,
    pub(crate) oracles: Vec<Box<dyn Oracle>>,
    pub(crate) comps: Vec<Compressor>,
    /// Decoded payloads of the last data round, indexed by sender.
    pub(crate) decoded: Vec<Vec<f32>>,
    pub(crate) g_buf: Vec<f32>,
    /// Reusable wire buffers, one per owned rank: together with the
    /// compressor scratch arenas these make the loopback data round
    /// allocation-free in steady state (the transport fabric necessarily
    /// hands an owned payload to the barrier each round).
    wire_bufs: Vec<Vec<u8>>,
    /// Reusable per-round exact-bit counts (rank order of `owned`).
    bits_buf: Vec<u64>,
    pub(crate) traffic: TrafficStats,
    pub(crate) links: LinkTraffic,
    /// Time-varying topology: edge-set changes observed so far (0 under
    /// static collectives; emitted as the `rewires` summary scalar).
    pub(crate) rewires: u64,
    /// The run-telemetry recorder (disabled by default; see
    /// [`crate::telemetry`]). Owned here so every family and both fabrics
    /// share one instrumentation seam.
    pub(crate) tele: Telemetry,
    /// Per-step stat schedule `U` (exact / gossip families).
    pub(crate) schedule: UpdateSchedule,
    /// Does this pipeline exchange statistics at all (local family)?
    adaptive: bool,
    update_every: usize,
    /// Local family: first stat exchange at the first sync on/after this.
    next_stat_due: usize,
}

impl RoundEngine {
    pub(crate) fn new(
        cfg: &ExperimentConfig,
        fabric: Fabric,
        collective: Arc<dyn Collective>,
        oracle_factory: Option<&OracleFactory>,
    ) -> Result<Self> {
        let op = crate::oracle::build_operator(&cfg.problem, cfg.seed)?;
        let d = op.dim();
        let k = cfg.workers;
        let root = Rng::seed_from(cfg.seed);
        let owned: Vec<usize> = match &fabric {
            Fabric::Loopback => (0..k).collect(),
            Fabric::Transport { rank, .. } => vec![*rank],
        };
        let guard = match &fabric {
            Fabric::Loopback => None,
            Fabric::Transport { transport, .. } => Some(PoisonGuard::new(transport.clone())),
        };
        let recv: Vec<Vec<usize>> = owned.iter().map(|&w| collective.recipients(w)).collect();
        let oracles: Vec<Box<dyn Oracle>> = owned
            .iter()
            .map(|&w| match oracle_factory {
                Some(f) => f(w, op.clone(), cfg),
                None => build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37),
            })
            .collect::<Result<_>>()?;
        let comps: Vec<Compressor> = owned
            .iter()
            .map(|&w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
            .collect::<Result<_>>()?;
        // THE stat-exchange predicate — one home for both fabrics and all
        // families ("does anything adapt" × "is the pipeline quantized").
        let n_owned = owned.len();
        let adaptive = cfg.quant.adapts() && comps[0].is_quantized();
        let schedule = if adaptive {
            UpdateSchedule::new(cfg.quant.update_every.min(10), cfg.quant.update_every)
        } else {
            UpdateSchedule::never()
        };
        Ok(RoundEngine {
            op,
            d,
            k,
            fabric,
            _guard: guard,
            collective,
            net: NetModel::from_config(&cfg.net),
            owned,
            recv,
            wire_bufs: vec![Vec::new(); n_owned],
            bits_buf: Vec::with_capacity(n_owned),
            oracles,
            comps,
            decoded: vec![vec![0.0f32; d]; k],
            g_buf: vec![0.0f32; d],
            traffic: TrafficStats::default(),
            links: LinkTraffic::new(),
            rewires: 0,
            tele: Telemetry::off(),
            schedule,
            adaptive,
            update_every: cfg.quant.update_every,
            next_stat_due: cfg.quant.update_every.min(10),
        })
    }

    /// Does this engine own all endpoints in-process?
    pub(crate) fn is_loopback(&self) -> bool {
        matches!(self.fabric, Fabric::Loopback)
    }

    /// Should this engine record metrics? (Loopback always; rank 0 of a
    /// transport group — the same split the seed's coordinators had.)
    pub(crate) fn is_metrics_rank(&self) -> bool {
        match &self.fabric {
            Fabric::Loopback => true,
            Fabric::Transport { rank, .. } => *rank == 0,
        }
    }

    /// The rank this engine drives under a transport fabric (`None` for
    /// loopback, which drives all of them).
    pub(crate) fn transport_rank(&self) -> Option<usize> {
        match &self.fabric {
            Fabric::Loopback => None,
            Fabric::Transport { rank, .. } => Some(*rank),
        }
    }

    /// Physical wire bytes this endpoint has observed, if the fabric
    /// actually moves bytes over a wire (socket transport). `None` for
    /// loopback and the in-process barrier.
    pub(crate) fn measured_wire(&self) -> Option<MeasuredWire> {
        match &self.fabric {
            Fabric::Loopback => None,
            Fabric::Transport { transport, .. } => transport.measured(),
        }
    }

    /// Rank-coordinated checkpoint barrier: every rank of a transport
    /// group contributes an out-of-band [`ckpt_marker`] for step `t` and
    /// validates everyone else's. After this returns `Ok`, all ranks are
    /// provably at the same iteration and no data/stat round is in flight
    /// — each rank's in-memory engine clone is one consistent global
    /// snapshot. Unbilled (out-of-band plane); no-op under loopback,
    /// where the single engine *is* the global state.
    pub(crate) fn checkpoint_barrier(&self, t: u64) -> Result<()> {
        match &self.fabric {
            Fabric::Loopback => Ok(()),
            Fabric::Transport { transport, rank } => {
                let got = transport.exchange(*rank, ckpt_marker(*rank, self.k, t), Plane::Oob)?;
                check_ckpt_markers(self.k, t, &got)
            }
        }
    }

    /// Re-attach a checkpointed transport-rank engine to a fresh
    /// [`Transport`] group — the elastic-restart primitive: kill a worker,
    /// rebuild the group (same `K`), resume every rank from its
    /// checkpoint. The engine state (oracles, compressors, RNG streams)
    /// belongs to one rank, so the checkpoint can only resume as that
    /// same rank.
    pub(crate) fn rebind_transport(
        &mut self,
        transport: Arc<dyn Transport>,
        rank: usize,
    ) -> Result<()> {
        match &self.fabric {
            Fabric::Loopback => Err(Error::Coordinator(
                "loopback checkpoints resume in-process; they have no transport rank to rebind"
                    .into(),
            )),
            Fabric::Transport { rank: own, transport: old } => {
                if *own != rank {
                    return Err(Error::Coordinator(format!(
                        "checkpoint holds rank {own}'s state; it cannot resume as rank {rank}"
                    )));
                }
                if old.kind() != transport.kind() {
                    return Err(Error::Coordinator(format!(
                        "checkpoint was taken on a `{}` fabric; it cannot resume on `{}`",
                        old.kind(),
                        transport.kind()
                    )));
                }
                if transport.peers() != self.k {
                    return Err(Error::Coordinator(format!(
                        "transport group of {} for a {}-worker checkpoint",
                        transport.peers(),
                        self.k
                    )));
                }
                self._guard = Some(PoisonGuard::new(transport.clone()));
                self.fabric = Fabric::Transport { transport, rank };
                Ok(())
            }
        }
    }

    /// Advance the collective's edge schedule to iteration `t`. Under a
    /// time-varying topology ([`crate::topo::RewiringGossip`]) the engine's
    /// cached receive sets are rebuilt whenever an epoch boundary is
    /// crossed; static collectives make this a no-op. Must run before the
    /// iteration's first data round so every rank swaps edge sets at the
    /// same `t`.
    pub(crate) fn begin_step(&mut self, t: u64) {
        if self.collective.advance_round(t) {
            for (i, &w) in self.owned.iter().enumerate() {
                self.recv[i] = self.collective.recipients(w);
            }
            self.rewires += 1;
            let rank = self.transport_rank().unwrap_or(0);
            self.tele.on_fault("rewire", rank, t);
        }
    }

    /// One data-plane round for vectors *sampled from the owned oracles*
    /// at the given query set. Returns the wire bits this round added.
    pub(crate) fn dual_exchange(&mut self, q: Query<'_>) -> Result<u64> {
        let t0 = Instant::now();
        let n = self.owned.len();
        self.bits_buf.clear();
        for i in 0..n {
            let x: &[f32] = match &q {
                Query::Shared(x) => x,
                Query::PerOwned(xs) => &xs[i],
            };
            let c0 = self.tele.clock();
            self.oracles[i].sample(x, &mut self.g_buf);
            self.tele.lap(c0, Stage::Sample);
            let b = self.comps[i].compress_timed(
                &self.g_buf,
                &mut self.wire_bufs[i],
                self.tele.spans_mut(),
            )?;
            self.bits_buf.push(b);
        }
        self.traffic.add_compute(t0.elapsed().as_secs_f64());
        self.note_ef();
        self.data_round()
    }

    /// One data-plane round for caller-provided vectors (model deltas).
    /// Returns the wire bits this round added (the `sync_bits` source).
    pub(crate) fn vector_exchange(&mut self, vecs: &[Vec<f32>]) -> Result<u64> {
        debug_assert_eq!(vecs.len(), self.owned.len());
        let t0 = Instant::now();
        self.bits_buf.clear();
        for (i, v) in vecs.iter().enumerate() {
            let b =
                self.comps[i].compress_timed(v, &mut self.wire_bufs[i], self.tele.spans_mut())?;
            self.bits_buf.push(b);
        }
        self.traffic.add_compute(t0.elapsed().as_secs_f64());
        self.note_ef();
        self.data_round()
    }

    /// Forward rank 0's error-feedback diagnostics (if the pipeline runs
    /// error feedback) to telemetry. Non-contractive pipelines report
    /// `None`, so EF-off runs never touch the `ef_*` telemetry marks.
    fn note_ef(&mut self) {
        if let Some((err_norm, delta)) = self.comps[0].ef_scalars() {
            self.tele.on_ef(err_norm, delta);
        }
    }

    /// Move one round of encoded payloads (`self.wire_bufs`, one per owned
    /// rank, rank order) and decode by sender into `self.decoded`.
    /// `self.bits_buf` holds the encoder-reported exact bit counts (used
    /// verbatim by the loopback fabric; the transport fabric accounts whole
    /// wire bytes — see module docs). Loopback steady state is
    /// allocation-free: reused wire buffers in, arena decodes out.
    fn data_round(&mut self) -> Result<u64> {
        let before = self.traffic.bits_sent;
        match &self.fabric {
            Fabric::Loopback => {
                let t0 = Instant::now();
                for w in 0..self.k {
                    self.comps[w].decompress_into(&self.wire_bufs[w], &mut self.decoded[w])?;
                }
                let dt = t0.elapsed().as_secs_f64();
                self.traffic.add_compute(dt);
                self.tele.span_secs(Stage::Decode, dt);
                // The same accounting `Collective::record_round` performs,
                // inlined so the modeled cost is visible to telemetry.
                let cost = self.collective.round_cost(&self.net, &self.bits_buf);
                self.traffic.record_modeled(cost.wire_bits, cost.messages, cost.secs);
                self.links.record(self.collective.as_ref(), &self.bits_buf);
                self.tele.on_data_round(cost.wire_bits, cost.secs, self.links.last_round());
            }
            Fabric::Transport { transport, rank } => {
                let rank = *rank;
                // The barrier takes ownership of the payload; the buffer is
                // rebuilt next round (a per-round allocation inherent to
                // moving bytes across threads).
                let payload = std::mem::take(&mut self.wire_bufs[0]);
                let (recv, bits) = self.collective.exchange(transport.as_ref(), rank, payload)?;
                let cost = self.collective.round_cost(&self.net, &bits);
                self.traffic.record_modeled(cost.wire_bits, cost.messages, cost.secs);
                if rank == 0 {
                    self.links.record(self.collective.as_ref(), &bits);
                }
                let t0 = Instant::now();
                for (sender, bytes) in &recv {
                    self.comps[0].decompress_into(bytes, &mut self.decoded[*sender])?;
                }
                let dt = t0.elapsed().as_secs_f64();
                self.traffic.add_compute(dt);
                self.tele.span_secs(Stage::Decode, dt);
                // Per-link deltas exist on the link-accounting rank only.
                if rank == 0 {
                    self.tele.on_data_round(cost.wire_bits, cost.secs, self.links.last_round());
                } else {
                    self.tele.on_data_round(cost.wire_bits, cost.secs, &[]);
                }
            }
        }
        Ok(self.traffic.bits_sent - before)
    }

    /// Control-plane stat exchange: pool every worker's serialized
    /// sufficient statistics (always accounted as a full-mesh round) and
    /// re-optimize levels / codecs / allocations in lockstep.
    pub(crate) fn stat_round(&mut self) -> Result<()> {
        let c0 = self.tele.clock();
        let bits_before = self.traffic.bits_sent;
        // `refreshed` = an update actually ran (codecs rebuilt) — observed
        // as an `updates()` delta so empty-payload no-ops stay invisible;
        // `changed` = some endpoint's level placement moved.
        let updates_before = self.comps[0].updates();
        let changed = match &self.fabric {
            Fabric::Loopback => pool_local_stats(&mut self.comps, &self.net, &mut self.traffic)?,
            Fabric::Transport { transport, rank } => {
                let payload = self.comps[0].stats_payload();
                let got = transport.exchange(*rank, payload, Plane::Control)?;
                let bits: Vec<u64> = got.iter().map(|p| 8 * p.len() as u64).collect();
                self.traffic.record_allgather(&bits, &self.net);
                let rank_order: Vec<&[u8]> = got.iter().map(|p| p.as_slice()).collect();
                self.comps[0].update_levels(&rank_order)?
            }
        };
        let refreshed = self.comps[0].updates() > updates_before;
        self.tele.lap(c0, Stage::Stat);
        self.tele.on_stat_round(self.traffic.bits_sent - bits_before, refreshed, changed);
        Ok(())
    }

    /// Per-step schedule `U` (exact / gossip families): stat round when
    /// `t ∈ U`. Returns whether one fired.
    pub(crate) fn maybe_per_step_stat(&mut self, t: usize) -> Result<bool> {
        if self.schedule.is_update(t) {
            self.stat_round()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Local-family schedule: stat round at the first sync on or after
    /// each due point (between syncs there is no wire to carry stats).
    /// Call only at sync steps. Returns whether one fired.
    pub(crate) fn maybe_local_stat(&mut self, t: usize) -> Result<bool> {
        if self.adaptive && self.update_every != 0 && t >= self.next_stat_due {
            self.stat_round()?;
            self.next_stat_due = t + self.update_every;
            return Ok(true);
        }
        Ok(false)
    }

    /// Owned rank `i`'s receive-set view of the last round (rank order
    /// within the closed neighborhood).
    pub(crate) fn view_of(&self, i: usize) -> Vec<Vec<f32>> {
        self.recv[i].iter().map(|&w| self.decoded[w].clone()).collect()
    }

    /// Cross-replica evaluation view from per-owned `(X_t, X̄)` pairs:
    /// loopback computes it directly; transport runs the out-of-band
    /// diagnostic allgather (every rank must call at the same step) and
    /// yields `Some` on rank 0 only.
    pub(crate) fn cross_view(
        &mut self,
        pairs: &[(Vec<f32>, Vec<f32>)],
    ) -> Result<Option<(Vec<Vec<f32>>, Vec<f32>)>> {
        match &self.fabric {
            Fabric::Loopback => {
                let iterates: Vec<Vec<f32>> = pairs.iter().map(|(x, _)| x.clone()).collect();
                let mut mean_avg = vec![0.0f32; self.d];
                for (_, a) in pairs {
                    for (m, &x) in mean_avg.iter_mut().zip(a.iter()) {
                        *m += x / self.k as f32;
                    }
                }
                Ok(Some((iterates, mean_avg)))
            }
            Fabric::Transport { transport, rank } => {
                let (x, erg) = &pairs[0];
                diag_exchange(*rank, self.k, self.d, transport.as_ref(), x, erg)
            }
        }
    }

    /// One private extra-gradient iteration for owned rank `i`'s replica
    /// (local family; borrows the oracle and scratch disjointly).
    pub(crate) fn local_round(
        &mut self,
        i: usize,
        rep: &mut crate::algo::LocalQGenX,
    ) -> Result<()> {
        rep.local_round(self.oracles[i].as_mut(), &mut self.g_buf)
    }

    // --- telemetry seam (see `crate::telemetry`) ---

    /// Install the telemetry recorder (SessionBuilder wiring).
    pub(crate) fn set_telemetry(&mut self, tele: Telemetry) {
        self.tele = tele;
    }

    /// The engine's telemetry recorder (disabled recorder when off).
    pub(crate) fn telemetry(&self) -> &Telemetry {
        &self.tele
    }

    /// Close telemetry step `t` — the session's end-of-step hook. Returns
    /// the closed [`StepRecord`] (None when telemetry is off).
    pub(crate) fn end_telemetry_step(&mut self, t: u64) -> Option<StepRecord> {
        self.tele.end_step(t)
    }

    /// Emit the telemetry `summary` event (per-layer cumulative bits for
    /// layer-wise pipelines, cumulative modeled per-link bytes, and — on
    /// a physical fabric — the endpoint's measured wire counters) and
    /// flush the JSONL sink. Safe to call more than once; no-op when off.
    pub(crate) fn finish_telemetry(&mut self) {
        if !self.tele.is_enabled() {
            return;
        }
        let link_totals = self.links.totals();
        let measured = self.measured_wire();
        match (self.comps[0].layer_names(), self.comps[0].layer_wire_bits()) {
            (Some(names), Some(bits)) => {
                let names = names.to_vec();
                let bits = bits.to_vec();
                self.tele.finish(Some((&names, &bits)), &link_totals, measured.as_ref());
            }
            _ => self.tele.finish(None, &link_totals, measured.as_ref()),
        }
    }
}

impl Clone for RoundEngine {
    fn clone(&self) -> Self {
        RoundEngine {
            op: self.op.clone(),
            d: self.d,
            k: self.k,
            fabric: self.fabric.clone(),
            _guard: match &self.fabric {
                Fabric::Loopback => None,
                Fabric::Transport { transport, .. } => {
                    Some(PoisonGuard::new(transport.clone()))
                }
            },
            collective: self.collective.clone(),
            net: self.net,
            owned: self.owned.clone(),
            recv: self.recv.clone(),
            oracles: self.oracles.iter().map(|o| o.clone_box()).collect(),
            comps: self.comps.clone(),
            decoded: self.decoded.clone(),
            g_buf: self.g_buf.clone(),
            wire_bufs: self.wire_bufs.clone(),
            bits_buf: self.bits_buf.clone(),
            traffic: self.traffic,
            links: self.links.clone(),
            rewires: self.rewires,
            tele: self.tele.clone(),
            schedule: self.schedule,
            adaptive: self.adaptive,
            update_every: self.update_every,
            next_stat_due: self.next_stat_due,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::{build_collective, Topology};

    fn engine(cfg: &ExperimentConfig) -> RoundEngine {
        let topo = Topology::from_config(&cfg.topo, cfg.workers).unwrap();
        let collective = build_collective(topo, cfg.workers).unwrap();
        RoundEngine::new(cfg, Fabric::Loopback, collective, None).unwrap()
    }

    fn base_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 3;
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 8;
        cfg.problem.noise = "absolute".into();
        cfg
    }

    #[test]
    fn loopback_round_decodes_every_sender_and_counts_bits() {
        let cfg = base_cfg();
        let mut eng = engine(&cfg);
        let x = vec![0.5f32; eng.d];
        let bits = eng.dual_exchange(Query::Shared(&x)).unwrap();
        assert!(bits > 0);
        assert_eq!(eng.traffic.bits_sent, bits);
        assert_eq!(eng.traffic.rounds, 1);
        assert_eq!(eng.decoded.len(), 3);
        assert!(eng.decoded.iter().all(|v| v.iter().all(|x| x.is_finite())));
        // Private oracles + private quantization randomness: the decoded
        // payloads genuinely differ across senders.
        assert_ne!(eng.decoded[0], eng.decoded[1]);
    }

    #[test]
    fn unified_stat_predicate_gates_fp32_out_of_stat_rounds() {
        // adaptive scheme + fp32 pipeline: nothing is quantized, so the
        // schedule must be disabled — the predicate both coordinators now
        // share (the seed's threaded runner derived it independently).
        let mut cfg = base_cfg();
        cfg.quant.mode = crate::config::QuantMode::Fp32;
        let eng = engine(&cfg);
        assert!((1..1000).all(|t| !eng.schedule.is_update(t)));
        // quantized adaptive pipeline: early warmup then periodic.
        let cfg_q = base_cfg();
        let eng_q = engine(&cfg_q);
        assert!(eng_q.schedule.is_update(cfg_q.quant.update_every.min(10)));
    }

    #[test]
    fn engine_clone_is_deep_and_streams_continue_identically() {
        let cfg = base_cfg();
        let mut a = engine(&cfg);
        let x = vec![0.25f32; a.d];
        a.dual_exchange(Query::Shared(&x)).unwrap();
        let mut b = a.clone();
        // Same RNG continuation on both sides → identical next rounds.
        let y = vec![-0.5f32; a.d];
        let bits_a = a.dual_exchange(Query::Shared(&y)).unwrap();
        let bits_b = b.dual_exchange(Query::Shared(&y)).unwrap();
        assert_eq!(bits_a, bits_b);
        assert_eq!(a.decoded, b.decoded);
        assert_eq!(a.traffic.bits_sent, b.traffic.bits_sent);
    }

    #[test]
    fn ckpt_markers_validate_rank_group_and_step() {
        let k = 3;
        let t = 42u64;
        let good: Vec<Arc<Vec<u8>>> =
            (0..k).map(|r| Arc::new(ckpt_marker(r, k, t))).collect();
        check_ckpt_markers(k, t, &good).unwrap();
        // Wrong step on one rank → loud mismatch naming the rank.
        let mut skew = good.clone();
        skew[1] = Arc::new(ckpt_marker(1, k, t + 1));
        let err = check_ckpt_markers(k, t, &skew).expect_err("step skew");
        assert!(err.to_string().contains("rank 1"), "got: {err}");
        // Wrong cardinality.
        assert!(check_ckpt_markers(k, t, &good[..2]).is_err());
        // Marker layout is the documented 20 bytes.
        let m = ckpt_marker(2, 4, 7);
        assert_eq!(m.len(), 20);
        assert_eq!(&m[..4], b"QCKP");
    }

    #[test]
    fn checkpoint_barrier_is_a_loopback_noop_and_syncs_transport_ranks() {
        let cfg = base_cfg();
        let eng = engine(&cfg);
        eng.checkpoint_barrier(5).unwrap();
        // Transport ranks: all three barriers at the same step succeed...
        let transport = crate::net::AllGather::new(cfg.workers);
        let engines: Vec<RoundEngine> = (0..cfg.workers)
            .map(|rank| {
                let topo = Topology::from_config(&cfg.topo, cfg.workers).unwrap();
                let collective = build_collective(topo, cfg.workers).unwrap();
                RoundEngine::new(
                    &cfg,
                    Fabric::Transport { transport: transport.clone(), rank },
                    collective,
                    None,
                )
                .unwrap()
            })
            .collect();
        std::thread::scope(|s| {
            for eng in &engines {
                s.spawn(move || eng.checkpoint_barrier(9).unwrap());
            }
        });
        // ... and a skewed step errors on every rank instead of silently
        // passing (the exchange itself succeeds; validation rejects).
        let results: Vec<Result<()>> = std::thread::scope(|s| {
            let handles: Vec<_> = engines
                .iter()
                .enumerate()
                .map(|(rank, eng)| {
                    s.spawn(move || eng.checkpoint_barrier(if rank == 2 { 11 } else { 10 }))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(results.iter().all(|r| r.is_err()), "every rank must observe the skew");
        let msg = results[0].as_ref().unwrap_err().to_string();
        assert!(msg.contains("checkpoint barrier"), "got: {msg}");
    }

    #[test]
    fn pool_local_stats_refreshes_every_endpoint_in_lockstep() {
        let cfg = base_cfg();
        let mut eng = engine(&cfg);
        let x = vec![1.0f32; eng.d];
        for _ in 0..5 {
            eng.dual_exchange(Query::Shared(&x)).unwrap();
        }
        let before = eng.traffic.bits_sent;
        eng.stat_round().unwrap();
        assert!(eng.traffic.bits_sent > before, "stat payloads are traffic");
        assert!(eng.comps.iter().all(|c| c.updates() == 1));
    }
}
